"""Figs. 5-6 — stragglers in only one layer (local devices XOR edge
servers), aggregator comparison + J/N/K sweeps."""
from __future__ import annotations

from repro.fl import BHFLSimulator

from .common import Csv, paper_lr_setting as setting, sim_kwargs


def main() -> dict:
    out = {}
    csv = Csv("fig56_single_layer")
    csv.row("layer", "variant", "final_acc", "best_acc")

    for layer, (dev, edge) in (("devices_only", ("temporary", "none")),
                               ("edges_only", ("none", "temporary"))):
        for agg in ("hieavg", "t_fedavg", "d_fedavg"):
            r = BHFLSimulator(setting(), agg, dev, edge,
                              **sim_kwargs()).run()
            csv.row(layer, agg, f"{r.accuracy[-1]:.4f}",
                    f"{r.accuracy.max():.4f}")
            out[(layer, agg)] = r.accuracy
        for k in (1, 4):
            r = BHFLSimulator(setting(k_edge_rounds=k), "hieavg", dev, edge,
                              **sim_kwargs()).run()
            csv.row(layer, f"hieavg_K{k}", f"{r.accuracy[-1]:.4f}",
                    f"{r.accuracy.max():.4f}")
            out[(layer, f"K{k}")] = r.accuracy
    csv.done()
    return out


if __name__ == "__main__":
    main()
