"""Fig. 3 — parameter sweeps (J devices, N edges, K edge rounds, straggler
count) on HieAvg with temporary stragglers.

Runs on the fully-jitted batched engine.  Shape-preserving sweeps (the
straggler fraction) execute as ONE ``run_sweep`` vmapped call; the J/N/K
sweeps change array shapes per point, so each point is its own compiled
engine run (``BHFLSimulator.run``)."""
from __future__ import annotations

from repro.fl import BHFLSimulator, run_sweep

from .common import FULL, Csv, setting, sim_kwargs


def main() -> dict:
    out = {}
    csv = Csv("fig3_sweeps")
    csv.row("param", "value", "final_acc", "best_acc")

    def emit(name, value, acc):
        csv.row(name, value, f"{acc[-1]:.4f}", f"{acc.max():.4f}")
        out[(name, value)] = acc

    def run(name, value, s, **kw):
        # steps_per_epoch=None -> one epoch over each device's own shard
        # (paper Sec. 6.1.5) so J/N sweeps hold the total data budget fixed
        r = BHFLSimulator(s, "hieavg", "temporary", "temporary",
                          **sim_kwargs(steps_per_epoch=None, **kw)).run()
        emit(name, value, r.accuracy)

    for j in ((3, 5, 8) if FULL else (3, 5, 8)):
        run("J_devices", j, setting(j_per_edge=j))
    for n in (3, 5, 8):
        run("N_edges", n, setting(n_edges=n))
    for k in (1, 2, 4):
        run("K_edge_rounds", k, setting(k_edge_rounds=k))

    # straggler-fraction sweep: same shapes at every point -> one batched call
    fracs = (0.2, 0.4)
    sw = run_sweep(setting(), overrides=[{"straggler_frac": f} for f in fracs],
                   **sim_kwargs(steps_per_epoch=None))
    for p, (ov, _seed) in enumerate(sw.points):
        emit("straggler_frac", ov["straggler_frac"], sw.accuracy[p])
    csv.done()
    return out


if __name__ == "__main__":
    main()
