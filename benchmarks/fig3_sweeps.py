"""Fig. 3 — parameter sweeps (J devices, N edges, K edge rounds, straggler
count) on HieAvg with temporary stragglers.

Runs on the sweep fabric (``repro.fl.sweep``): the J/N/K sweeps change
array shapes per point, which used to force one compiled engine run per
point.  The shape-bucketed planner groups the WHOLE figure (topology +
straggler-fraction grid) into a few compatible-shape buckets — one
compiled, mesh-sharded call each — instead of padding every point to the
single grid maximum (which cost this mixed grid several-fold padding
compute; the printed plan shows the bucket shapes and the padded-compute
waste the heuristic settled for)."""
from __future__ import annotations

from repro.fl import plan_sweep, run_plan

from .common import Csv, setting, sim_kwargs


def main() -> dict:
    out = {}
    csv = Csv("fig3_sweeps")
    csv.row("param", "value", "final_acc", "best_acc")

    # one bucketed plan: every row of Fig. 3 is a point of the same sweep.
    # steps_per_epoch=None -> one epoch over each device's own shard
    # (paper Sec. 6.1.5) so J/N sweeps hold the total data budget fixed;
    # the planner pads the per-point step counts to each bucket's max.
    grid = [("J_devices", "j_per_edge", (3, 5, 8)),
            ("N_edges", "n_edges", (3, 5, 8)),
            ("K_edge_rounds", "k_edge_rounds", (1, 2, 4)),
            ("straggler_frac", "straggler_frac", (0.2, 0.4))]
    names, overrides = [], []
    for name, field, values in grid:
        for v in values:
            names.append((name, v))
            overrides.append({field: v})

    plan = plan_sweep(setting(), overrides=overrides,
                      **sim_kwargs(steps_per_epoch=None))
    for line in plan.describe().splitlines():
        print("# " + line)
    sw = run_plan(plan)
    if len(sw.points) != len(names):       # single seed: 1 point per row
        raise RuntimeError("fig3 grid points and row labels diverged")
    for p, (name, value) in enumerate(names):
        acc, _, _ = sw.trajectory(p)
        csv.row(name, value, f"{acc[-1]:.4f}", f"{acc.max():.4f}")
        out[(name, value)] = acc
    csv.done()
    return out


if __name__ == "__main__":
    main()
