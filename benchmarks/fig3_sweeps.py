"""Fig. 3 — parameter sweeps (J devices, N edges, K edge rounds, straggler
count) on HieAvg with temporary stragglers."""
from __future__ import annotations

import dataclasses

from repro.fl import BHFLSimulator

from .common import FULL, Csv, setting, sim_kwargs


def main() -> dict:
    out = {}
    csv = Csv("fig3_sweeps")
    csv.row("param", "value", "final_acc", "best_acc")

    def run(name, value, s, **kw):
        # steps_per_epoch=None -> one epoch over each device's own shard
        # (paper Sec. 6.1.5) so J/N sweeps hold the total data budget fixed
        r = BHFLSimulator(s, "hieavg", "temporary", "temporary",
                          **sim_kwargs(steps_per_epoch=None, **kw)).run()
        csv.row(name, value, f"{r.accuracy[-1]:.4f}",
                f"{r.accuracy.max():.4f}")
        out[(name, value)] = r.accuracy

    for j in ((3, 5, 8) if FULL else (3, 5, 8)):
        run("J_devices", j, setting(j_per_edge=j))
    for n in (3, 5, 8):
        run("N_edges", n, setting(n_edges=n))
    for k in (1, 2, 4):
        run("K_edge_rounds", k, setting(k_edge_rounds=k))
    for frac in (0.2, 0.4):
        run("straggler_frac", frac, setting(straggler_frac=frac))
    csv.done()
    return out


if __name__ == "__main__":
    main()
