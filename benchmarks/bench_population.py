"""Population-scale cohort sampling — writes ``BENCH_population.json``.

Two claims from the population plane (``repro.fl.population``):

1. **Rounds/sec is flat in population size.**  The simulator's per-round
   work is O(cohort): cohort ids are drawn by index, profiles are gathered
   into slots, and only the gathered ``[N, J_cohort]`` cohort ever touches
   device memory.  We run the SAME fixed-cohort deployment against device
   populations 10^3 → 10^6 (the profile store is prebuilt once per size and
   excluded from timing, like a registration database would be) and check
   best-of rounds/sec stays within 10% across four orders of magnitude —
   a materializing simulator would slow down ~1000x.

2. **Accuracy vs staleness discount.**  The delayed-gradient aggregator
   (``aggregation="delayed_grad"``) lets round-``t`` stragglers submit into
   round ``t+1`` with weight ``beta**k'``.  A mixed-aggregation sweep —
   HieAvg next to a ``staleness_discount`` grid, ONE batched traced-switched
   call — produces the accuracy-vs-beta curve under temporary stragglers.

  PYTHONPATH=src python -m benchmarks.run --only population --emit-json
  PYTHONPATH=src python -m benchmarks.bench_population --smoke   # CI
"""
from __future__ import annotations

import dataclasses
import json
import time

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import BHFLSimulator, run_sweep
from repro.fl.population import DevicePopulation, PopulationSpec

from .common import FULL, Csv

T_ROUNDS = 20
N_EDGES = 3
J_COHORT = 5
KW = dict(n_train=2000, n_test=400, steps_per_epoch=1)
POPULATIONS = (10**3, 10**4, 10**5, 10**6)
BETAS = (0.25, 0.5, 0.75, 0.9) if FULL else (0.5, 0.9)
REPS = 5


def _setting():
    return dataclasses.replace(REDUCED, t_global_rounds=T_ROUNDS,
                               n_edges=N_EDGES)


def _store(size: int) -> DevicePopulation:
    # Prebuilt once per size: the store is the only O(population) object
    # (three profile arrays), the fleet-registration analogue.  Everything
    # timed below is gather-by-index O(cohort).
    spec = PopulationSpec(size=size, j_cohort=J_COHORT)
    return DevicePopulation(spec, n_classes=REDUCED.n_classes, seed=0)


def main(emit_json: bool = True, smoke: bool = False) -> dict:
    populations = POPULATIONS[:2] if smoke else POPULATIONS
    reps = 1 if smoke else REPS
    csv = Csv("bench_population")
    csv.row("population", "seconds", "rounds_per_sec")

    # Interleave reps across sizes (size-major would fold machine drift —
    # CPU frequency ramps, allocator warm-up — into the size axis) and take
    # the best rep per size, the same best-of-after-warm-up methodology as
    # common.best_of.
    runners = {}
    for size in populations:
        pop = _store(size)
        runners[size] = (lambda pop=pop: BHFLSimulator(
            _setting(), "hieavg", "temporary", "temporary",
            population=pop, **KW).run())
    best = {size: float("inf") for size in populations}
    for fn in runners.values():      # warm-up pass: jit caches hot
        fn()
    for _ in range(reps):
        for size, fn in runners.items():
            t0 = time.time()
            fn()
            best[size] = min(best[size], time.time() - t0)
    rps = {}
    for size in populations:
        rps[size] = T_ROUNDS / best[size]
        csv.row(size, f"{best[size]:.2f}", f"{rps[size]:.2f}")

    vals = list(rps.values())
    flat_ratio = max(vals) / min(vals)
    csv.row("flat_ratio(max/min)", "", f"{flat_ratio:.3f}")

    # accuracy vs staleness discount: HieAvg + a delayed_grad beta grid as
    # one mixed-aggregation batched call (plan aggregator = "switched")
    overrides = [{"aggregation": "hieavg"}] + [
        {"aggregation": "delayed_grad", "staleness_discount": b}
        for b in BETAS]
    res = run_sweep(_setting(), seeds=(0,), overrides=overrides, **KW)
    acc = [float(a[-1]) for a in res.accuracy]
    curve = {"hieavg": acc[0], **{f"delayed_grad_beta={b}": a
                                  for b, a in zip(BETAS, acc[1:])}}
    for name, a in curve.items():
        csv.row(name, "", f"acc={a:.3f}")

    out = {
        "setting": "REDUCED",
        "n_edges": N_EDGES,
        "j_cohort": J_COHORT,
        "t_global_rounds": T_ROUNDS,
        "reps": reps,
        "rounds_per_sec": {str(k): round(v, 3) for k, v in rps.items()},
        "flat_ratio": round(flat_ratio, 4),
        "flat_within_10pct": bool(flat_ratio <= 1.10),
        "staleness_betas": list(BETAS),
        "final_accuracy": {k: round(v, 4) for k, v in curve.items()},
    }
    if emit_json:
        with open("BENCH_population.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote BENCH_population.json (flat_ratio "
              f"{out['flat_ratio']}, within_10pct "
              f"{out['flat_within_10pct']})")
    csv.done()
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI budget: 2 population sizes, 1 rep")
    args = ap.parse_args()
    main(smoke=args.smoke)
