"""Sweep-fabric throughput — writes ``BENCH_sweep.json``.

Measures points/sec for the same REDUCED 4-point *shape-changing* grid
(topology varies per point — impossible to batch before the sweep fabric)
driven five ways:

  * ``legacy_loop``     — one ``BHFLSimulator.run_legacy`` per point
                          (the original per-edge Python loop),
  * ``engine_per_point``— one compiled ``BHFLSimulator.run`` per point
                          (each point its own shapes, own compile),
  * ``vmap``            — the fabric's single-device path with
                          ``max_buckets=1``: all points padded to the
                          single grid max, one ``vmap(run_engine)`` call,
  * ``bucketed``        — the shape-bucketed planner (default knobs): the
                          grid splits into a few shape buckets, one
                          compiled call each, trading extra compiles for
                          less padded compute (the ``padded_flop_frac``
                          column shows the fraction of each plan's compute
                          volume that is padding),
  * ``sharded``         — the fabric's ``shard_map`` path over the mesh
                          ``data`` axis (measured in a 4-host-device
                          subprocess via ``--xla_force_host_platform_
                          device_count``; the vmap path is re-measured
                          there so the two are compared on equal devices;
                          single-bucket, since 1-2-point buckets cannot
                          divide 4 devices).

Timings are best-of-``REPS`` after a warm-up run (jit caches hot), like
``bench_engine``.  The grid is intentionally small (T=10, 1 local step) so
the numbers track orchestration + padding overhead, not training FLOPs —
which also means the bucketed row undersells bucketing (per-bucket compile
overhead is amortized, but padded-FLOP savings only matter when real
training FLOPs dominate; the ``padded_flop_frac`` column is the
scale-independent signal).

  PYTHONPATH=src python -m benchmarks.run --only sweep --emit-json
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

from repro.configs.bhfl_cnn import REDUCED

from .common import Csv, best_of

T_ROUNDS = 10
KW = dict(n_train=1500, n_test=300, steps_per_epoch=1, normalize=True)
REPS = 2
N_CHILD_DEVICES = 4
_CHILD_ENV = "BENCH_SWEEP_CHILD"
_CHILD_MARK = "BENCH_SWEEP_CHILD_JSON:"

# a shape-changing grid: every point has a different topology/round count
OVERRIDES = [
    {"n_edges": 3},
    {"n_edges": 5},
    {"j_per_edge": 3},
    {"k_edge_rounds": 1},
]


def _setting():
    return dataclasses.replace(REDUCED, t_global_rounds=T_ROUNDS)


def _measure(placement: str, **sweep_kw) -> float:
    from repro.fl import run_sweep
    return best_of(lambda: run_sweep(_setting(), overrides=OVERRIDES,
                                     placement=placement, **sweep_kw,
                                     **KW), REPS)


def _padding_stats(**sweep_kw) -> dict:
    """Padding accounting for the plan a ``_measure`` call with the SAME
    ``sweep_kw`` executes — pass identical kwargs to both so the reported
    fractions always describe the plan that was actually timed."""
    from repro.fl import plan_sweep
    return plan_sweep(_setting(), overrides=OVERRIDES, **sweep_kw,
                      **KW).padding_stats()


def _child_main() -> None:
    """Runs inside the forced-4-host-device subprocess."""
    import jax
    # single-bucket: forced shard needs the whole 4-point grid in one
    # stack (auto buckets of 1-2 points cannot divide 4 devices)
    t_vmap = _measure("vmap", max_buckets=1)
    t_shard = _measure("shard", max_buckets=1)
    print(_CHILD_MARK + json.dumps({
        "devices": len(jax.devices()),
        "vmap_seconds": t_vmap,
        "sharded_seconds": t_shard,
    }))


def _spawn_child() -> dict | None:
    env = dict(os.environ)
    env[_CHILD_ENV] = "1"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " --xla_force_host_"
                        f"platform_device_count={N_CHILD_DEVICES}").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_sweep"],
            capture_output=True, text=True, env=env, timeout=1200)
    except subprocess.TimeoutExpired:
        sys.stderr.write("# bench_sweep: 4-device child timed out; "
                         "emitting single-device numbers only\n")
        return None
    for line in proc.stdout.splitlines():
        if line.startswith(_CHILD_MARK):
            return json.loads(line[len(_CHILD_MARK):])
    sys.stderr.write(proc.stdout + proc.stderr)
    return None


def main(emit_json: bool = True) -> dict:
    if os.environ.get(_CHILD_ENV) == "1":
        _child_main()
        return {}

    from repro.fl import BHFLSimulator

    csv = Csv("bench_sweep")
    csv.row("path", "devices", "seconds", "points_per_sec",
            "padded_flop_frac")
    n_pts = len(OVERRIDES)

    def per_point(method):
        for ov in OVERRIDES:
            sim = BHFLSimulator(dataclasses.replace(_setting(), **ov),
                                "hieavg", "temporary", "temporary", **KW)
            getattr(sim, method)()

    t_legacy = best_of(lambda: per_point("run_legacy"), REPS)
    csv.row("legacy_loop", 1, f"{t_legacy:.2f}", f"{n_pts / t_legacy:.2f}",
            "0.000")
    t_point = best_of(lambda: per_point("run"), REPS)
    csv.row("engine_per_point", 1, f"{t_point:.2f}",
            f"{n_pts / t_point:.2f}", "0.000")
    stats_single = _padding_stats(max_buckets=1)
    frac_single = stats_single["padded_flop_frac"]
    t_vmap = _measure("vmap", max_buckets=1)
    csv.row("vmap", 1, f"{t_vmap:.2f}", f"{n_pts / t_vmap:.2f}",
            f"{frac_single:.3f}")
    stats_bucketed = _padding_stats()     # default bucketing knobs...
    frac_bucketed = stats_bucketed["padded_flop_frac"]
    t_bucketed = _measure("vmap")         # ...same knobs as the timed run
    csv.row("bucketed", 1, f"{t_bucketed:.2f}",
            f"{n_pts / t_bucketed:.2f}", f"{frac_bucketed:.3f}")

    child = _spawn_child()
    if child is not None:
        csv.row("vmap", child["devices"], f"{child['vmap_seconds']:.2f}",
                f"{n_pts / child['vmap_seconds']:.2f}",
                f"{frac_single:.3f}")
        csv.row("sharded", child["devices"],
                f"{child['sharded_seconds']:.2f}",
                f"{n_pts / child['sharded_seconds']:.2f}",
                f"{frac_single:.3f}")

    out = {
        "setting": "REDUCED",
        "grid": OVERRIDES,
        "t_global_rounds": T_ROUNDS,
        "steps_per_epoch": KW["steps_per_epoch"],
        "reps": REPS,
        "points": n_pts,
        "legacy_points_per_sec": round(n_pts / t_legacy, 3),
        "engine_per_point_points_per_sec": round(n_pts / t_point, 3),
        "vmap_points_per_sec": round(n_pts / t_vmap, 3),
        "vmap_speedup_vs_legacy": round(t_legacy / t_vmap, 2),
        "bucketed_points_per_sec": round(n_pts / t_bucketed, 3),
        "bucketed_speedup_vs_single_bucket": round(t_vmap / t_bucketed, 2),
        "bucket_count": len(stats_bucketed["buckets"]),
        "single_bucket_padded_flop_frac": round(frac_single, 4),
        "bucketed_padded_flop_frac": round(frac_bucketed, 4),
    }
    if child is not None:
        out.update({
            "child_devices": child["devices"],
            "vmap_points_per_sec_4dev": round(
                n_pts / child["vmap_seconds"], 3),
            "sharded_points_per_sec_4dev": round(
                n_pts / child["sharded_seconds"], 3),
            "sharded_speedup_vs_legacy": round(
                t_legacy / child["sharded_seconds"], 2),
            "sharded_speedup_vs_vmap_4dev": round(
                child["vmap_seconds"] / child["sharded_seconds"], 2),
        })
    if emit_json:
        with open("BENCH_sweep.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote BENCH_sweep.json (vmap "
              f"{out['vmap_speedup_vs_legacy']}x vs legacy, bucketed "
              f"{out['bucket_count']} programs cut padding "
              f"{frac_single:.0%} -> {frac_bucketed:.0%}"
              + (f", sharded {out['sharded_speedup_vs_legacy']}x"
                 if child is not None else "") + ")")
    csv.done()
    return out


if __name__ == "__main__":
    main()
