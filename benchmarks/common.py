"""Shared benchmark plumbing: budget control + CSV emission."""
from __future__ import annotations

import dataclasses
import os
import sys
import time

from repro.configs.bhfl_cnn import REDUCED, BHFLSetting

FULL = os.environ.get("BENCH_FULL", "0") == "1"

# Benchmark budget: FULL reproduces the paper's round counts; the default
# is a CPU-friendly reduction that preserves every qualitative claim.
T_ROUNDS = 100 if FULL else 30
N_TRAIN = 6000 if FULL else 2000
N_TEST = 1000 if FULL else 400
STEPS = 10
STOP_ROUND = 40 if FULL else 10


def setting(**kw) -> BHFLSetting:
    base = dataclasses.replace(REDUCED, t_global_rounds=T_ROUNDS,
                               permanent_stop_round=STOP_ROUND)
    return dataclasses.replace(base, **kw)


def paper_lr_setting(**kw) -> BHFLSetting:
    """Paper-faithful learning rate (Sec. 6.1.5: 0.001, decay 0.9).

    HieAvg's delta extrapolation assumes smooth per-round weight drift;
    with the surrogate-tuned large rate (0.02) the extrapolated estimates
    are noisy enough that plain T_FedAvg wins under permanent stragglers —
    the aggregator comparisons (fig2/fig56) therefore run at the paper's
    own rate, where the paper's ordering reproduces.  The lr-sensitivity
    itself is reported in EXPERIMENTS.md.
    """
    base = dataclasses.replace(REDUCED, t_global_rounds=max(T_ROUNDS, 40),
                               permanent_stop_round=STOP_ROUND,
                               lr0=1e-3, lr_decay=0.9)
    return dataclasses.replace(base, **kw)


def sim_kwargs(**kw) -> dict:
    out = dict(n_train=N_TRAIN, n_test=N_TEST, steps_per_epoch=STEPS,
               normalize=True)
    out.update(kw)
    return out


def best_of(fn, reps: int = 3) -> float:
    """Warm-up once (jit caches hot), then best-of-``reps`` wall seconds.

    The shared timing methodology for every BENCH_*.json artifact — change
    it here, not per-bench, so the numbers stay comparable.
    """
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best


def interleaved_best_of(fns: dict, reps: int = 3) -> dict:
    """Best-of-``reps`` wall seconds for *competing* variants, interleaved.

    Warm every variant once (jit caches hot), then take ``reps`` passes of
    the whole variant set — variant A, variant B, ... per pass — so slow
    drift in box load (thermal, co-tenants) spreads across all variants
    instead of reading as a variant difference.  This is the methodology
    for every head-to-head comparison row (legacy vs engine, auto vs xla);
    ``best_of`` remains for standalone timings.

    Returns ``{name: best_seconds}`` in the input order.
    """
    for fn in fns.values():
        fn()
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.time()
            fn()
            best[name] = min(best[name], time.time() - t0)
    return best


class Csv:
    def __init__(self, name: str):
        self.name = name
        self.t0 = time.time()
        print(f"# --- {name} ---")

    def row(self, *cells):
        print(",".join(str(c) for c in cells))
        sys.stdout.flush()

    def done(self):
        print(f"# {self.name}: {time.time() - self.t0:.1f}s")
