"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh):
  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

Sources: ``compiled.cost_analysis()`` (FLOPs, bytes) and the HLO collective
census from ``repro.launch.dryrun``.  Hardware: TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

IMPORTANT unit notes:
 * cost_analysis and the HLO census are PER-PARTITION (SPMD module), so
   terms divide by per-chip rates only.
 * XLA's HloCostAnalysis counts a while (scan) body ONCE, so raw HLO FLOPs
   undercount layer-scanned models by ~n_layers.  The collective census is
   while-aware (dryrun multiplies by trip counts).  For compute we use the
   analytic MODEL_FLOPS (with a remat factor for training); for memory we
   scale HLO bytes by the analytic/HLO flops ratio (the scan-body
   correction; embed/unembed traffic outside the scan is small).
   ``hlo_flops`` is reported as the body-once lower bound.
 * MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) is the *useful* math;
   the HFL train step additionally pays the remat recompute (~+2·N·D).
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import INPUT_SHAPES, count_params, param_specs


def active_params(arch: str) -> float:
    """Parameters touched per token (MoE: shared + top-k routed + attn)."""
    cfg = get_config(arch)
    total = count_params(param_specs(cfg))
    if cfg.moe is None:
        return float(total)
    m = cfg.moe
    fe = m.d_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * fe
    routed_total = cfg.n_layers * m.n_experts * per_expert
    routed_active = cfg.n_layers * m.top_k * per_expert
    return float(total - routed_total + routed_active)


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·D for training; 2·N_active·D for inference forward."""
    shape = INPUT_SHAPES[shape_name]
    n_act = active_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # the HFL step runs one local SGD step per client: fwd+bwd = 6ND
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def roofline_row(rec: dict) -> dict:
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    hlo_flops = rec.get("flops", 0.0)        # per-partition, body-once
    hbm = rec.get("hlo_bytes", 0.0)          # per-partition, body-once
    coll = rec.get("collectives", {}).get("total_bytes", 0)  # while-aware

    shape = INPUT_SHAPES[rec["shape"]]
    remat = 8.0 / 6.0 if shape.kind == "train" else 1.0
    mf = model_flops(rec["arch"], rec["shape"]) / chips      # useful/chip
    exec_flops = mf * remat                                  # executed/chip
    # scan-body correction for memory traffic (see module docstring)
    scale = min(max(exec_flops / hlo_flops, 1.0), 128.0) if hlo_flops > 0 \
        else 1.0

    t_compute = exec_flops / PEAK_FLOPS_BF16
    t_memory = hbm * scale / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    total = t_compute + t_memory + t_coll
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_chip": mf, "hlo_flops": hlo_flops,
        "useful_ratio": mf / exec_flops,
        "roofline_frac": t_compute / total if total > 0 else float("nan"),
        "mem_gib": rec.get("bytes_per_device", 0) / 2**30,
    }


def load_results(paths: list[str]) -> list[dict]:
    """Merge dry-run JSONs; later files override earlier (arch,shape,mesh)."""
    merged: dict = {}
    for p in paths:
        if not os.path.exists(p):
            continue
        for rec in json.load(open(p)):
            merged[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return list(merged.values())


DEFAULT_FILES = ["dryrun_results.json", "dryrun_dsv2.json",
                 "dryrun_grok.json", "dryrun_grok_train.json",
                 "dryrun_dsv2_train.json", "dryrun_rg.json",
                 "dryrun_perf.json"]


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--files", nargs="*", default=DEFAULT_FILES)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)

    rows = []
    print("# --- roofline ---")
    print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "roofline_frac,mem_GiB")
    for rec in sorted(load_results(args.files),
                      key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if "skipped" in rec:
            print(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
                  f"SKIP({rec['skipped'][:40]}...)")
            continue
        if "error" in rec:
            print(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
                  f"ERROR({rec['error'][:60]})")
            continue
        r = roofline_row(rec)
        rows.append(r)
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
              f"{r['t_collective_s']:.4f},{r['dominant']},"
              f"{r['roofline_frac']:.3f},{r['mem_gib']:.2f}")
    return rows


if __name__ == "__main__":
    main()
