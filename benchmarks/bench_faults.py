"""Fault-injection degradation curves — writes ``BENCH_faults.json``.

The chaos plane's headline artifact: a fault_rate × consensus-protocol
grid — edge crash–recover rates (MTBF/MTTR Markov processes) and
chain-validator churn with bounded quorum stall-and-retry — compiled as
ONE padded sweep call (every fault field is a data-batched sweep field,
``repro.fl.sweep.BATCHED_FIELDS``), reporting per-protocol degradation
curves: final accuracy, accuracy drop vs the protocol's clean baseline,
and total simulated clock (stall backoff included via the traced C2
accounting) as the fault rate rises.

The validator-churn axis runs with ``max_stall_rounds`` headroom so
transiently below-quorum rounds stall and recover instead of raising —
the stall seconds are visible as the clock gap vs the clean baseline.

  PYTHONPATH=src python -m benchmarks.run --only faults --emit-json

``smoke=True`` (the ``--smoke`` flag, used by
tests/test_bench_emission.py) shrinks the grid/rounds/data so the whole
emission path runs in seconds.
"""
from __future__ import annotations

import dataclasses
import json
import time

from repro.configs.bhfl_cnn import REDUCED

from .common import Csv

T_ROUNDS = 10
KW = dict(n_train=1500, n_test=300, steps_per_epoch=1, normalize=True)
PROTOCOLS = ("raft", "pofel", "sharded")
EDGE_RATES = (0.0, 0.1, 0.2, 0.4)     # with recover_rate=0.5 (MTTR 2 rounds)
VAL_RATES = (0.1, 0.2)                # with recover_rate=0.8 + stall budget
EDGE_RECOVER = 0.5
VAL_RECOVER = 0.8
STALL_ROUNDS = 5


def _overrides(edge_rates, val_rates) -> list[dict]:
    """The degradation grid: per protocol, a clean baseline (the 0.0 edge
    rate), the edge crash-recover axis, and the validator-churn axis."""
    out = []
    for proto in PROTOCOLS:
        for r in edge_rates:
            out.append({"consensus": proto, "edge_fail_rate": r,
                        "edge_recover_rate": EDGE_RECOVER})
        for r in val_rates:
            out.append({"consensus": proto, "val_fail_rate": r,
                        "val_recover_rate": VAL_RECOVER,
                        "max_stall_rounds": STALL_ROUNDS})
    return out


def main(emit_json: bool = True, smoke: bool = False) -> dict:
    from repro.fl import sweep as _sweep

    t_rounds = 3 if smoke else T_ROUNDS
    kw = dict(KW, n_train=300, n_test=100) if smoke else KW
    edge_rates = (0.0, 0.3) if smoke else EDGE_RATES
    val_rates = (0.2,) if smoke else VAL_RATES
    setting = dataclasses.replace(REDUCED, t_global_rounds=t_rounds)
    overrides = _overrides(edge_rates, val_rates)

    csv = Csv("bench_faults")
    csv.row("protocol", "axis", "rate", "final_acc", "acc_drop",
            "final_clock_s")

    t0 = time.time()
    plan = _sweep.plan_sweep(setting, overrides=overrides, **kw)
    res = _sweep.run_plan(plan)
    elapsed = time.time() - t0

    # per-protocol curves: index the result rows back by their overrides
    curves: dict = {p: {"edge_fail": [], "val_fail": []} for p in PROTOCOLS}
    base_acc: dict = {}
    for p, (ov, _seed) in enumerate(res.points):
        clock, acc = res.latency_trajectory(p)
        if ov.get("edge_fail_rate", 0.0) == 0.0 and "val_fail_rate" not in ov:
            base_acc[ov["consensus"]] = float(acc[-1])
    for p, (ov, _seed) in enumerate(res.points):
        proto = ov["consensus"]
        clock, acc = res.latency_trajectory(p)
        axis = "val_fail" if "val_fail_rate" in ov else "edge_fail"
        rate = ov.get("val_fail_rate", ov.get("edge_fail_rate", 0.0))
        row = {
            "rate": float(rate),
            "final_acc": round(float(acc[-1]), 4),
            "acc_drop": round(base_acc[proto] - float(acc[-1]), 4),
            "final_clock_s": round(float(clock[-1]), 3),
        }
        curves[proto][axis].append(row)
        csv.row(proto, axis, f"{rate:.2f}", f"{row['final_acc']:.4f}",
                f"{row['acc_drop']:.4f}", f"{row['final_clock_s']:.1f}")
    for proto in curves:
        for axis in curves[proto]:
            curves[proto][axis].sort(key=lambda r: r["rate"])

    out = {
        "setting": "REDUCED",
        "t_global_rounds": t_rounds,
        "points": len(res.points),
        "buckets": len(plan.buckets),         # the one-padded-call claim
        "seconds": round(elapsed, 2),
        "edge_recover_rate": EDGE_RECOVER,
        "val_recover_rate": VAL_RECOVER,
        "max_stall_rounds": STALL_ROUNDS,
        "protocols": list(PROTOCOLS),
        "curves": curves,
    }
    if emit_json:
        with open("BENCH_faults.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote BENCH_faults.json ({len(res.points)} fault points "
              f"in {len(plan.buckets)} compiled call(s), {elapsed:.1f}s)")
    csv.done()
    return out


if __name__ == "__main__":
    main()
