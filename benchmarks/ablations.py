"""Beyond-paper ablations of HieAvg's own knobs.

The paper fixes γ0 = λ = 0.9 and never ablates them, and (per
EXPERIMENTS.md) its eq. (4) normalization matters enormously.  Three
sweeps, all under permanent stragglers (the stress case):

  a) γ0 sweep            — how much estimated-weight should count at k'=1
  b) λ sweep             — how fast the trust in the estimate decays
  c) faithful vs normalized eq. (4), and straggler-fraction × aggregator
"""
from __future__ import annotations

from repro.fl import BHFLSimulator

from .common import Csv, paper_lr_setting, sim_kwargs


def main() -> dict:
    out = {}
    csv = Csv("ablations")
    csv.row("ablation", "value", "final_acc", "best_acc")
    base = paper_lr_setting()

    def run(tag, value, s, **kw):
        r = BHFLSimulator(s, kw.pop("agg", "hieavg"), "permanent",
                          "permanent", **sim_kwargs(**kw)).run()
        csv.row(tag, value, f"{r.accuracy[-1]:.4f}", f"{r.accuracy.max():.4f}")
        out[(tag, value)] = r.accuracy

    import dataclasses
    for g0 in (0.3, 0.6, 0.9, 0.99):
        run("gamma0", g0, dataclasses.replace(base, gamma0=g0))
    for lam in (0.5, 0.9, 0.99):
        run("lambda", lam, dataclasses.replace(base, lam=lam))
    run("eq4_faithful", "normalize=False", base, normalize=False)
    run("eq4_normalized", "normalize=True", base, normalize=True)
    for frac in (0.2, 0.4):
        s = dataclasses.replace(base, straggler_frac=frac)
        for agg in ("hieavg", "t_fedavg"):
            run(f"frac_{frac}", agg, s, agg=agg)
    csv.done()
    return out


if __name__ == "__main__":
    main()
