"""Fig. 2 — HieAvg vs W/O-Stragglers vs T_FedAvg vs D_FedAvg, under
permanent (a) and temporary (b) stragglers.

Each run executes on the fully-jitted batched engine (``BHFLSimulator.run``
delegates to ``repro.fl.engine``); the aggregator is a static program
branch, so the eight (kind, aggregator) cells are separate compiled calls
that share one compilation per shape."""
from __future__ import annotations

from repro.fl import BHFLSimulator

from .common import Csv, paper_lr_setting, sim_kwargs


def main() -> dict:
    out = {}
    csv = Csv("fig2_convergence")
    csv.row("straggler_kind", "aggregator", "final_acc", "best_acc",
            "mean_last5")
    s = paper_lr_setting()
    for kind in ("permanent", "temporary"):
        runs = {}
        runs["wo_stragglers"] = BHFLSimulator(
            s, "fedavg", "none", "none", **sim_kwargs()).run()
        for agg in ("hieavg", "t_fedavg", "d_fedavg"):
            runs[agg] = BHFLSimulator(s, agg, kind, kind,
                                      **sim_kwargs()).run()
        for name, r in runs.items():
            csv.row(kind, name, f"{r.accuracy[-1]:.4f}",
                    f"{r.accuracy.max():.4f}",
                    f"{r.accuracy[-5:].mean():.4f}")
        out[kind] = {k: v.accuracy for k, v in runs.items()}
    csv.done()
    return out


if __name__ == "__main__":
    main()
