"""Kernel micro-benchmarks + kernel-plane engine rows — ``BENCH_kernels.json``.

Two sections:

  * **micro** — the fused HieAvg aggregation kernel vs the XLA reference
    path on realistic [n, L] leaves: analytic HBM traffic per path (the
    quantity the fused kernel actually optimizes — ~7 full passes for the
    XLA chain vs ~2 for the one-pass kernel), measured wall time of both,
    and an allclose check.  On this CPU container the kernel runs through
    the Pallas *interpreter* (``fused_backend`` records which), so its
    wall time is NOT the TPU figure of merit — the HBM model is; on
    TPU/GPU the same harness times the compiled ``pallas_call``.
  * **engine** — rounds/sec of the same REDUCED deployment as
    ``bench_engine`` with the kernel plane on (``kernel_mode="auto"``) vs
    forced off (``"xla"``).  On CPU "auto" resolves to the XLA reference
    dispatch, so the acceptance bar is parity: auto within a few percent
    of ``BENCH_engine.json``'s engine rounds/sec (the dispatch layer adds
    no overhead).  On accelerators the same row measures the fused-kernel
    speedup.

  PYTHONPATH=src python -m benchmarks.run --only kernels --emit-json
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.bhfl_cnn import REDUCED
from repro.core import hieavg
from repro.kernels import resolve_kernel_mode
from repro.kernels.ops import fused_edge_aggregate

from .common import Csv, best_of

# same budget as bench_engine so the engine rows are comparable to
# BENCH_engine.json
T_ROUNDS = 20
ENGINE_KW = dict(n_train=2000, n_test=400, steps_per_epoch=1,
                 normalize=True)
REPS = 3


def hbm_traffic_gb(n: int, l: int, bytes_per: int = 4) -> tuple[float, float]:
    """(XLA-path, fused-path) HBM bytes for one edge aggregation.

    XLA path (observed from the jaxpr of hieavg.edge_aggregate): reads w,
    prev, dmean for the estimate, again for the mix, again for both history
    updates, and writes agg + 2 history trees ≈ 7 full passes.
    Fused: read w/prev/dmean once, write agg + 2 histories once ≈ 2 passes.
    """
    leaf = n * l * bytes_per
    xla = 7 * leaf
    fused = (3 * leaf) + (2 * leaf + l * bytes_per)
    return xla / 1e9, fused / 1e9


def _time_ms(fn, reps: int = REPS) -> float:
    """Wall ms via the shared ``best_of`` methodology (warm-up + best-of-
    min), like every other BENCH_*.json artifact."""
    return best_of(lambda: jax.block_until_ready(fn()), reps) * 1e3


def _micro_rows(csv: Csv) -> list[dict]:
    rows = []
    for n, l in ((5, 100_000), (25, 100_000), (16, 400_000)):
        ks = jax.random.split(jax.random.key(0), 3)
        w = jax.random.normal(ks[0], (n, l))
        stacked = {"p": w}
        hist = hieavg.init_history(stacked)
        mask = jnp.arange(n) % 5 != 0
        xla_ms = _time_ms(
            lambda: hieavg.edge_aggregate(stacked, mask, hist)[0]["p"])
        fused_ms = _time_ms(
            lambda: fused_edge_aggregate(stacked, mask, hist)[0]["p"])
        agg, _ = hieavg.edge_aggregate(stacked, mask, hist)
        agg_f, _ = fused_edge_aggregate(stacked, mask, hist)
        ok = bool(jnp.allclose(agg["p"], agg_f["p"], atol=1e-4))
        xla_gb, fused_gb = hbm_traffic_gb(n, l)
        csv.row("hieavg_agg", n, l, f"{xla_gb:.2f}", f"{fused_gb:.2f}",
                f"{xla_gb / fused_gb:.1f}x", f"{xla_ms:.1f}",
                f"{fused_ms:.1f}", ok)
        rows.append({"kernel": "hieavg_agg", "n": n, "L": l,
                     "xla_hbm_gb": round(xla_gb, 3),
                     "fused_hbm_gb": round(fused_gb, 3),
                     "hbm_reduction": round(xla_gb / fused_gb, 2),
                     "xla_ms": round(xla_ms, 2),
                     "fused_ms": round(fused_ms, 2),
                     "allclose": ok})
    return rows


def _engine_rounds_per_sec() -> dict[str, float]:
    """rounds/sec for kernel_mode auto vs forced xla, reps INTERLEAVED:
    measuring the two modes back-to-back per rep (instead of all-auto
    then all-xla) keeps slow drift in box load from reading as a mode
    difference — on CPU the two are the same compiled program and should
    measure equal up to noise."""
    from repro.fl import BHFLSimulator
    setting = dataclasses.replace(REDUCED, t_global_rounds=T_ROUNDS)

    def once(mode):
        BHFLSimulator(setting, "hieavg", "temporary", "temporary",
                      kernel_mode=mode, **ENGINE_KW).run()

    best = {"auto": float("inf"), "xla": float("inf")}
    for mode in best:
        once(mode)                                   # warm the jit caches
    for _ in range(REPS):
        for mode in best:
            t0 = time.time()
            once(mode)
            best[mode] = min(best[mode], time.time() - t0)
    return {mode: T_ROUNDS / t for mode, t in best.items()}


def main(emit_json: bool = False) -> dict:
    csv = Csv("kernel_bench")
    # engine rows first: the interpret-mode micro bench below loads the
    # box for seconds at a time, which would skew an engine timing that
    # followed it
    auto_mode = resolve_kernel_mode("auto")
    rps = _engine_rounds_per_sec()
    rps_auto, rps_xla = rps["auto"], rps["xla"]

    csv.row("kernel", "n", "L", "xla_hbm_GB", "fused_hbm_GB", "reduction",
            "xla_ms", "fused_ms", "allclose")
    micro = _micro_rows(csv)
    # engine throughput is a different table — own header, own columns
    csv.row("engine_path", "kernel_mode", "rounds_per_sec")
    csv.row("engine_kernel_plane_auto", auto_mode, f"{rps_auto:.2f}")
    csv.row("engine_kernel_plane_off", "xla", f"{rps_xla:.2f}")

    out = {
        "backend": jax.default_backend(),
        "fused_backend": "interpret" if auto_mode == "xla" else "pallas",
        "auto_resolves_to": auto_mode,
        "micro": micro,
        "engine_t_global_rounds": T_ROUNDS,
        "engine_auto_rounds_per_sec": round(rps_auto, 3),
        "engine_xla_rounds_per_sec": round(rps_xla, 3),
        "engine_auto_vs_xla": round(rps_auto / rps_xla, 3),
    }
    if emit_json:
        with open("BENCH_kernels.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote BENCH_kernels.json (engine auto {rps_auto:.2f} r/s"
              f" vs xla {rps_xla:.2f} r/s; auto -> {auto_mode})")
    csv.done()
    return out


if __name__ == "__main__":
    main()
