"""Kernel micro-benchmarks + kernel-plane engine rows — ``BENCH_kernels.json``.

Two sections:

  * **micro** — every fused kernel vs its XLA reference path on realistic
    shapes: analytic HBM traffic per path (the quantity the fused kernels
    actually optimize), measured wall time of both (reps interleaved via
    ``interleaved_best_of`` so box-load drift never reads as a path
    difference), and an allclose check.  Rows:

      - ``hieavg_agg``     — warm edge aggregation (estimate+mix+history),
      - ``conv3x3``        — im2col matmul with fused bias+ReLU epilogue,
      - ``eval_head``      — logits → argmax → correct-count, one pass,
      - ``coef_agg_pair``  — the generalized coefficient aggregate (pair
        form: the delayed-gradient fill + weighted mean in one pass).

    On this CPU container the kernels run through the Pallas *interpreter*
    (``fused_backend`` records which), so their wall time is NOT the TPU
    figure of merit — the HBM model is; on TPU/GPU the same harness times
    the compiled ``pallas_call``.
  * **engine** — rounds/sec of the same REDUCED deployment as
    ``bench_engine`` with the kernel plane on (``kernel_mode="auto"``) vs
    forced off (``"xla"``), reps interleaved.  On CPU "auto" resolves to
    the XLA reference dispatch, so the acceptance bar is parity: auto
    within a few percent of xla (the dispatch layer adds no overhead).
    On accelerators the same row measures the fused-kernel speedup.

  The JSON carries the ``padded_flop_frac``-style kernel-plane coverage
  block (``fused_phase_coverage``): which engine round phases run fused
  under the measured mode, and under a fused mode — conv fwd/bwd, SGD,
  warm+cold aggregation, fedavg, delayed-grad, and the eval head, i.e.
  the whole round.

  PYTHONPATH=src python -m benchmarks.run --only kernels --emit-json
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.configs.bhfl_cnn import REDUCED
from repro.core import hieavg
from repro.kernels import fused_phase_coverage, resolve_kernel_mode
from repro.kernels import ops, ref

from .bench_engine import kernel_plane_record
from .common import Csv, interleaved_best_of

# same budget as bench_engine so the engine rows are comparable to
# BENCH_engine.json
T_ROUNDS = 20
ENGINE_KW = dict(n_train=2000, n_test=400, steps_per_epoch=1,
                 normalize=True)
REPS = 3


def hbm_traffic_gb(n: int, l: int, bytes_per: int = 4) -> tuple[float, float]:
    """(XLA-path, fused-path) HBM bytes for one edge aggregation.

    XLA path (observed from the jaxpr of hieavg.edge_aggregate): reads w,
    prev, dmean for the estimate, again for the mix, again for both history
    updates, and writes agg + 2 history trees ≈ 7 full passes.
    Fused: read w/prev/dmean once, write agg + 2 histories once ≈ 2 passes.
    """
    leaf = n * l * bytes_per
    xla = 7 * leaf
    fused = (3 * leaf) + (2 * leaf + l * bytes_per)
    return xla / 1e9, fused / 1e9


def conv_traffic_gb(m: int, k: int, n: int,
                    bytes_per: int = 4) -> tuple[float, float]:
    """(XLA, fused) HBM bytes for the conv matmul + bias + ReLU.

    Both paths read the im2col cols ``[M, K]`` and weights once; XLA then
    writes the matmul result and re-reads/re-writes it for the separate
    bias-add + ReLU (3 output passes), the fused epilogue writes it once.
    """
    cols, out = m * k * bytes_per, m * n * bytes_per
    return (cols + 3 * out) / 1e9, (cols + out) / 1e9


def eval_traffic_gb(m: int, f: int, c: int,
                    bytes_per: int = 4) -> tuple[float, float]:
    """(XLA, fused) HBM bytes for the eval head.

    XLA materializes the ``[M, C]`` logits (write) then re-reads them for
    the argmax; the fused kernel folds argmax+compare+count into the
    matmul tiles and never writes logits to HBM (output: one count/tile).
    """
    feats, logits = m * f * bytes_per, m * c * bytes_per
    return (feats + 2 * logits) / 1e9, feats / 1e9


def pair_traffic_gb(n: int, l: int, bytes_per: int = 4) -> tuple[float, float]:
    """(XLA, fused) HBM bytes for the pair-form coefficient aggregate.

    XLA (the ``delayed_grad`` reference): fill ``where(mask, w, pending)``
    reads both ``[n, L]`` operands and writes the filled intermediate,
    then the weighted mean re-reads it ≈ 4 full passes; the fused kernel
    reads each operand once and writes the ``[L]`` aggregate.
    """
    leaf, out = n * l * bytes_per, l * bytes_per
    return (4 * leaf + out) / 1e9, (2 * leaf + out) / 1e9


def _pair_ms(xla_fn, fused_fn) -> tuple[float, float]:
    """Interleaved best-of wall ms for one (xla, fused) micro pair."""
    best = interleaved_best_of({
        "xla": lambda: jax.block_until_ready(xla_fn()),
        "fused": lambda: jax.block_until_ready(fused_fn()),
    }, REPS)
    return best["xla"] * 1e3, best["fused"] * 1e3


def _row(csv: Csv, name, n, l, xla_gb, fused_gb, xla_ms, fused_ms,
         ok) -> dict:
    csv.row(name, n, l, f"{xla_gb:.3f}", f"{fused_gb:.3f}",
            f"{xla_gb / fused_gb:.1f}x", f"{xla_ms:.1f}",
            f"{fused_ms:.1f}", ok)
    return {"kernel": name, "n": n, "L": l,
            "xla_hbm_gb": round(xla_gb, 3),
            "fused_hbm_gb": round(fused_gb, 3),
            "hbm_reduction": round(xla_gb / fused_gb, 2),
            "xla_ms": round(xla_ms, 2), "fused_ms": round(fused_ms, 2),
            "allclose": ok}


def _micro_rows(csv: Csv) -> list[dict]:
    rows = []
    # warm edge aggregation (the original row set)
    for n, l in ((5, 100_000), (25, 100_000), (16, 400_000)):
        ks = jax.random.split(jax.random.key(0), 3)
        w = jax.random.normal(ks[0], (n, l))
        stacked = {"p": w}
        hist = hieavg.init_history(stacked)
        mask = jnp.arange(n) % 5 != 0
        xla_ms, fused_ms = _pair_ms(
            lambda: hieavg.edge_aggregate(stacked, mask, hist)[0]["p"],
            lambda: ops.fused_edge_aggregate(stacked, mask, hist)[0]["p"])
        agg, _ = hieavg.edge_aggregate(stacked, mask, hist)
        agg_f, _ = ops.fused_edge_aggregate(stacked, mask, hist)
        ok = bool(jnp.allclose(agg["p"], agg_f["p"], atol=1e-4))
        xla_gb, fused_gb = hbm_traffic_gb(n, l)
        rows.append(_row(csv, "hieavg_agg", n, l, xla_gb, fused_gb,
                         xla_ms, fused_ms, ok))

    # fused conv3x3 + bias + ReLU (the training fwd hot-spot)
    ks = jax.random.split(jax.random.key(1), 3)
    b_, hw, cin, cout = 16, 28, 8, 16
    x = jax.random.normal(ks[0], (b_, hw, hw, cin))
    w3 = jax.random.normal(ks[1], (3, 3, cin, cout)) * 0.1
    bb = jax.random.normal(ks[2], (cout,)) * 0.1
    xla_conv = jax.jit(ref.conv3x3_bias_relu_ref)
    fused_conv = jax.jit(lambda x, w, b: ops.conv3x3_bias_relu(
        x, w, b, interpret=True))
    xla_ms, fused_ms = _pair_ms(lambda: xla_conv(x, w3, bb),
                                lambda: fused_conv(x, w3, bb))
    ok = bool(jnp.allclose(xla_conv(x, w3, bb), fused_conv(x, w3, bb),
                           atol=1e-4))
    m = b_ * hw * hw
    xla_gb, fused_gb = conv_traffic_gb(m, 9 * cin, cout)
    rows.append(_row(csv, "conv3x3", m, 9 * cin * cout, xla_gb, fused_gb,
                     xla_ms, fused_ms, ok))

    # fused eval head (logits -> argmax -> count, one pass)
    ks = jax.random.split(jax.random.key(2), 4)
    m, f, c = 400, 784, 10
    feats = jax.random.normal(ks[0], (m, f))
    wmat = jax.random.normal(ks[1], (f, c)) * 0.05
    bias = jax.random.normal(ks[2], (c,)) * 0.05
    labels = jax.random.randint(ks[3], (m,), 0, c)
    xla_eval = jax.jit(ref.eval_head_ref)
    fused_eval = jax.jit(lambda fe, w, b, y: ops.eval_head(
        fe, w, b, y, interpret=True))
    xla_ms, fused_ms = _pair_ms(
        lambda: xla_eval(feats, wmat, bias, labels),
        lambda: fused_eval(feats, wmat, bias, labels))
    ok = bool(xla_eval(feats, wmat, bias, labels)
              == fused_eval(feats, wmat, bias, labels))
    xla_gb, fused_gb = eval_traffic_gb(m, f, c)
    rows.append(_row(csv, "eval_head", m, f, xla_gb, fused_gb,
                     xla_ms, fused_ms, ok))

    # generalized coefficient aggregate, pair form (delayed-grad fill+mean)
    ks = jax.random.split(jax.random.key(3), 4)
    n, l = 25, 100_000
    w = jax.random.normal(ks[0], (n, l))
    aux = jax.random.normal(ks[1], (n, l))
    coef = jax.nn.softmax(jax.random.normal(ks[2], (n,)))
    msk = (jax.random.uniform(ks[3], (n,)) > 0.3).astype(jnp.float32)
    ca, cb = coef * msk, coef * (1.0 - msk)
    xla_pair = jax.jit(ref.coef_agg_pair_ref)
    fused_pair = jax.jit(lambda w, a, ca, cb: ops.coef_agg_pair(
        w, a, ca, cb, interpret=True))
    xla_ms, fused_ms = _pair_ms(lambda: xla_pair(w, aux, ca, cb),
                                lambda: fused_pair(w, aux, ca, cb))
    ok = bool(jnp.allclose(xla_pair(w, aux, ca, cb),
                           fused_pair(w, aux, ca, cb), atol=1e-5))
    xla_gb, fused_gb = pair_traffic_gb(n, l)
    rows.append(_row(csv, "coef_agg_pair", n, l, xla_gb, fused_gb,
                     xla_ms, fused_ms, ok))
    return rows


def _engine_rounds_per_sec() -> dict[str, float]:
    """rounds/sec for kernel_mode auto vs forced xla, reps interleaved
    (``interleaved_best_of``): on CPU the two are the same compiled
    program and should measure equal up to noise."""
    from repro.fl import BHFLSimulator
    setting = dataclasses.replace(REDUCED, t_global_rounds=T_ROUNDS)

    def once(mode):
        BHFLSimulator(setting, "hieavg", "temporary", "temporary",
                      kernel_mode=mode, **ENGINE_KW).run()

    best = interleaved_best_of({
        "auto": lambda: once("auto"),
        "xla": lambda: once("xla"),
    }, REPS)
    return {mode: T_ROUNDS / t for mode, t in best.items()}


def main(emit_json: bool = False) -> dict:
    csv = Csv("kernel_bench")
    # engine rows first: the interpret-mode micro bench below loads the
    # box for seconds at a time, which would skew an engine timing that
    # followed it
    auto_mode = resolve_kernel_mode("auto")
    rps = _engine_rounds_per_sec()
    rps_auto, rps_xla = rps["auto"], rps["xla"]

    csv.row("kernel", "n", "L", "xla_hbm_GB", "fused_hbm_GB", "reduction",
            "xla_ms", "fused_ms", "allclose")
    micro = _micro_rows(csv)
    # engine throughput is a different table — own header, own columns
    kp = kernel_plane_record("auto")
    csv.row("engine_path", "kernel_mode", "rounds_per_sec",
            "fused_phase_frac")
    csv.row("engine_kernel_plane_auto", auto_mode, f"{rps_auto:.2f}",
            f"{kp['fused_phase_frac']:.3f}")
    csv.row("engine_kernel_plane_off", "xla", f"{rps_xla:.2f}", "0.000")

    out = {
        "backend": jax.default_backend(),
        "fused_backend": "interpret" if auto_mode == "xla" else "pallas",
        "auto_resolves_to": auto_mode,
        "micro": micro,
        "kernel_plane": kp,
        # which phases the plane covers when a fused mode is forced on —
        # the full round (coverage is mode-independent once fused)
        "fused_phases_when_on": fused_phase_coverage("interpret"),
        "engine_t_global_rounds": T_ROUNDS,
        "engine_auto_rounds_per_sec": round(rps_auto, 3),
        "engine_xla_rounds_per_sec": round(rps_xla, 3),
        "engine_auto_vs_xla": round(rps_auto / rps_xla, 3),
    }
    if emit_json:
        with open("BENCH_kernels.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote BENCH_kernels.json (engine auto {rps_auto:.2f} r/s"
              f" vs xla {rps_xla:.2f} r/s; auto -> {auto_mode})")
    csv.done()
    return out


if __name__ == "__main__":
    main()
