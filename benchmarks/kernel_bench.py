"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode, so wall
time is NOT the TPU figure of merit; we report (a) analytic HBM traffic
per path — the quantity the fused kernel actually optimizes — and (b) CPU
wall time of the XLA (unfused) reference paths as a sanity check that the
fused semantics match at realistic sizes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import hieavg
from repro.kernels.ops import fused_edge_aggregate

from .common import Csv


def hbm_traffic_gb(n: int, l: int, bytes_per: int = 4) -> tuple[float, float]:
    """(XLA-path, fused-path) HBM bytes for one edge aggregation.

    XLA path (observed from the jaxpr of hieavg.edge_aggregate): reads w,
    prev, dmean for the estimate, again for the mix, again for both history
    updates, and writes agg + 2 history trees ≈ 7 full passes.
    Fused: read w/prev/dmean once, write agg + 2 histories once ≈ 2 passes.
    """
    leaf = n * l * bytes_per
    xla = 7 * leaf
    fused = (3 * leaf) + (2 * leaf + l * bytes_per)
    return xla / 1e9, fused / 1e9


def main() -> None:
    csv = Csv("kernel_bench")
    csv.row("kernel", "n", "L", "xla_hbm_GB", "fused_hbm_GB", "reduction",
            "xla_cpu_ms", "allclose")
    for n, l in ((5, 100_000), (25, 100_000), (16, 400_000)):
        ks = jax.random.split(jax.random.key(0), 3)
        w = jax.random.normal(ks[0], (n, l))
        stacked = {"p": w}
        hist = hieavg.init_history(stacked)
        mask = jnp.arange(n) % 5 != 0
        # XLA path timing
        agg, h2 = hieavg.edge_aggregate(stacked, mask, hist)  # compile
        jax.block_until_ready(agg)
        t0 = time.time()
        for _ in range(3):
            agg, h2 = hieavg.edge_aggregate(stacked, mask, hist)
        jax.block_until_ready(agg)
        ms = (time.time() - t0) / 3 * 1e3
        # fused correctness (interpret mode is a python loop — check the
        # smallest size only; tests/test_kernels sweeps more)
        if l <= 100_000:
            agg_f, _ = fused_edge_aggregate(stacked, mask, hist)
            ok = bool(jnp.allclose(agg["p"], agg_f["p"], atol=1e-4))
        else:
            ok = "skipped"
        xla_gb, fused_gb = hbm_traffic_gb(n, l)
        csv.row("hieavg_agg", n, l, f"{xla_gb:.2f}", f"{fused_gb:.2f}",
                f"{xla_gb / fused_gb:.1f}x", f"{ms:.1f}", ok)
    csv.done()


if __name__ == "__main__":
    main()
