"""Fig. 7 — (a) computing/communication latency vs per-device data size;
(b) optimal K* vs blockchain consensus latency.

Both panels run on the latency fabric as ONE compiled sweep
(``plan_sweep``/``execute_plan`` via ``run_sweep``): panel (a) scales the
per-round compute draw (``lp_device`` ∝ images/device, anchored at the
paper's measured 1.67 s @ 2400 images) and reads the *measured* simulated
round time off the engine clock next to the Sec. 5.1 expectation; panel
(b) crosses the consensus multiplier with a K grid and reports the
*empirical* K* (fastest simulated time to a target accuracy,
``SweepResult.k_star_empirical``) next to the theoretical ``omega_bound``
K* (``optimize_k`` under C1/C2 with the statistical Raft consensus
model).  Panel (c) rides the consensus zoo through the SAME call —
``consensus`` is a data-batched sweep field — and reads measured
per-round latency/energy next to each protocol's closed-form models.
The latency constants are the paper's measured numbers (0.51 s
device<->edge transfer, 0.05 s edge<->edge link — Sec. 6.2.2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.bhfl_cnn import REDUCED
from repro.core import (CONSENSUS_MODELS, BoundParams, LatencyParams,
                        RaftParams, expected_consensus_latency, omega_bound,
                        optimize_k)
from repro.fl import run_sweep

from .common import Csv

T_ROUNDS = 10
KW = dict(n_train=1500, n_test=300, steps_per_epoch=2, normalize=True)

IMAGES = (600, 1200, 2400, 4800)
CONS_MULTS = (1, 5, 10, 20, 40)
K_GRID = (1, 2, 4)
ACC_FRAC = 0.6     # empirical-K* target: 60% of the grid's best accuracy

# panel (c): the consensus zoo under a stall-inducing multiplier — same
# shapes as panel (a), so the protocol axis stays pure data in the one call
ZOO_POINTS = ({"consensus": "raft", "consensus_mult": 20.0},
              {"consensus": "pofel", "consensus_mult": 20.0},
              {"consensus": "sharded", "consensus_mult": 20.0},
              {"consensus": "sharded", "n_shards": 4,
               "consensus_mult": 20.0})


def _setting():
    return dataclasses.replace(REDUCED, t_global_rounds=T_ROUNDS)


def sweep_overrides() -> tuple[list[dict], int, int]:
    """The one fig7 grid: panel (a), then (b), then (c) consensus-zoo
    points.

    Returns (overrides, index where panel (b) starts, index where panel
    (c) starts).
    """
    ovs = [{"lp_device": 1.67 * imgs / 2400.0} for imgs in IMAGES]
    split_b = len(ovs)
    ovs += [{"consensus_mult": float(m), "k_edge_rounds": k}
            for m in CONS_MULTS for k in K_GRID]
    split_c = len(ovs)
    ovs += [dict(p) for p in ZOO_POINTS]
    return ovs, split_b, split_c


def main() -> dict:
    out = {}
    csv = Csv("fig7_latency")
    s = _setting()
    ovs, split, split_c = sweep_overrides()
    # ONE compiled padded call — max_buckets=1 pins the documented fig7
    # protocol (and the E4 numbers) even though the K grid is shape-mixed
    # and default bucketing would split it into a few cheaper programs
    sw = run_sweep(s, overrides=ovs, max_buckets=1, **KW)

    # (a) latency vs data size: compute scales linearly with images/device
    csv.row("images_per_device", "model_round_s", "measured_round_s")
    for p, imgs in enumerate(IMAGES):
        lp = ovs[p]["lp_device"]
        model = 2 * s.lm_device + lp                     # Sec. 5.1 E[round]
        clock, _ = sw.latency_trajectory(p)
        # measured simulated time per edge round (clock is per global
        # round: K edge rounds + hop + any consensus stall)
        meas = float(clock[-1]) / (len(clock) * s.k_edge_rounds)
        csv.row(imgs, f"{model:.3f}", f"{meas:.3f}")
        out[("latency", imgs)] = meas

    # (b) K* vs consensus latency: theoretical (C1/C2 on the statistical
    # Raft model) next to empirical (fastest simulated time-to-accuracy).
    # The engine's clock charges the FULL per-round consensus draw
    # (election + commit, not the election-amortized steady state), so the
    # theoretical solve must see the same L_bc — include_election=True —
    # or the two selectors would optimize under different latencies.
    bp = BoundParams()
    lp = LatencyParams(T=T_ROUNDS, N=s.n_edges, J=s.j_per_edge)
    base_lbc = expected_consensus_latency(
        RaftParams(link_latency=s.link_latency), s.n_edges)
    target = ACC_FRAC * float(sw.accuracy[split:split_c].max())
    csv.row("consensus_latency_s", "k_star_theory", "k_star_empirical",
            "time_to_acc_s")
    for i, m in enumerate(CONS_MULTS):
        lbc = base_lbc * m
        res = optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=25.0,
                         consensus_latency=lbc)
        k_th = res.k_star if res else -1
        pts = [split + i * len(K_GRID) + j for j in range(len(K_GRID))]
        times = [sw.time_to_accuracy(p, target) for p in pts]
        best = int(np.argmin(times))
        k_emp = K_GRID[best] if np.isfinite(times[best]) else -1
        csv.row(f"{lbc:.3f}", k_th, k_emp, f"{times[best]:.1f}")
        out[("kstar", round(lbc, 3))] = k_th
        out[("kstar_emp", round(lbc, 3))] = k_emp

    # (c) consensus zoo: measured per-round energy off the engine's energy
    # axis next to each protocol's closed-form expectations (the same
    # forms the consensus_mc MC pins hold ≤5%; T=10 rounds here is a
    # report, not a pin)
    csv.row("consensus", "round_time_s", "energy_j_per_round",
            "model_latency_s", "model_energy_j")
    for i, ov in enumerate(ovs[split_c:]):
        p = split_c + i
        name = ov["consensus"]
        spec = CONSENSUS_MODELS[name]
        params = spec.make_params(s.link_latency, ov.get("n_shards", 2))
        clock, energy = sw.energy_trajectory(p)
        meas_t = float(clock[-1]) / len(clock)
        meas_e = float(energy[-1]) / len(energy)
        label = f"{name}/{ov['n_shards']}sh" if "n_shards" in ov else name
        csv.row(label, f"{meas_t:.3f}", f"{meas_e:.3f}",
                f"{spec.expected_latency(params, s.n_edges):.3f}",
                f"{spec.expected_energy(params, s.n_edges):.3f}")
        out[("zoo", label)] = meas_e
    csv.done()
    return out


if __name__ == "__main__":
    main()
