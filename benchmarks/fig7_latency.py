"""Fig. 7 — (a) computing/communication latency vs per-device data size;
(b) optimal K* vs blockchain consensus latency.

The latency numbers use the paper's measured constants (1.67 s local
training at 2400 images, 0.51 s device<->edge transfer of a 20 KB model,
0.05 s edge<->edge link — Sec. 6.2.2) through the Sec. 5.1 model.
"""
from __future__ import annotations

import numpy as np

from repro.core import (BoundParams, LatencyParams, RaftChain, omega_bound,
                        optimize_k)

from .common import Csv


def main() -> dict:
    out = {}
    csv = Csv("fig7_latency")

    # (a) latency vs data size: compute scales linearly with images/device
    csv.row("images_per_device", "compute_s", "comm_s", "round_total_s")
    for imgs in (600, 1200, 2400, 4800):
        lp = 1.67 * imgs / 2400.0       # paper: 1.67 s at 2400 images
        lm = 0.51                       # 20 KB model transfer
        csv.row(imgs, f"{lp:.3f}", f"{lm:.3f}", f"{2 * lm + lp:.3f}")
        out[("latency", imgs)] = 2 * lm + lp

    # (b) K* vs consensus latency (constraint C2 pushes K* up)
    csv.row("consensus_latency_s", "k_star", "total_latency_s")
    bp = BoundParams()
    p = LatencyParams()
    chain = RaftChain(p.N)
    base_lbc = chain.consensus_latency()
    for mult in (1, 5, 10, 20, 40):
        lbc = base_lbc * mult
        res = optimize_k(p, lambda k: omega_bound(k, bp), omega_bar=25.0,
                         consensus_latency=lbc)
        k = res.k_star if res else -1
        lat = res.latency if res else float("nan")
        csv.row(f"{lbc:.3f}", k, f"{lat:.1f}")
        out[("kstar", round(lbc, 3))] = k
    csv.done()
    return out


if __name__ == "__main__":
    main()
