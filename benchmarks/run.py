"""Benchmark entry point: one harness per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run            # reduced budget
  BENCH_FULL=1 PYTHONPATH=src python -m benchmarks.run
  PYTHONPATH=src python -m benchmarks.run --only fig2,roofline
  PYTHONPATH=src python -m benchmarks.run --only engine --emit-json
"""
from __future__ import annotations

import argparse
import functools
import time

from . import (ablations, bench_engine, bench_faults, bench_latency,
               bench_population, bench_sweep, fig2_convergence, fig3_sweeps,
               fig4_heterogeneity, fig56_single_layer, fig7_latency,
               kernel_bench, roofline)

SUITES = {
    "fig2": fig2_convergence.main,
    "fig3": fig3_sweeps.main,
    "fig4": fig4_heterogeneity.main,
    "fig56": fig56_single_layer.main,
    "fig7": fig7_latency.main,
    "ablations": ablations.main,
    "kernels": kernel_bench.main,
    "roofline": lambda: roofline.main([]),
    "engine": bench_engine.main,
    "sweep": bench_sweep.main,
    "latency": bench_latency.main,
    "population": bench_population.main,
    "faults": bench_faults.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--emit-json", action="store_true",
                    help="write BENCH_*.json (engine/sweep/latency/kernels)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale budget (latency/faults suites; used "
                         "by the bench-emission smoke test)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    suites = dict(SUITES)
    suites["engine"] = functools.partial(bench_engine.main,
                                         emit_json=args.emit_json)
    suites["sweep"] = functools.partial(bench_sweep.main,
                                        emit_json=args.emit_json)
    suites["latency"] = functools.partial(bench_latency.main,
                                          emit_json=args.emit_json,
                                          smoke=args.smoke)
    suites["kernels"] = functools.partial(kernel_bench.main,
                                          emit_json=args.emit_json)
    suites["population"] = functools.partial(bench_population.main,
                                             emit_json=args.emit_json)
    suites["faults"] = functools.partial(bench_faults.main,
                                         emit_json=args.emit_json,
                                         smoke=args.smoke)
    t0 = time.time()
    for name in names:
        suites[name]()
    print(f"# all benchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
