"""Engine vs legacy-loop throughput — writes ``BENCH_engine.json``.

Measures rounds/sec for the same REDUCED (N=5 edges) deployment driven by

  * the legacy per-edge Python loop (``BHFLSimulator.run_legacy``), and
  * the fully-jitted batched engine (``BHFLSimulator.run`` →
    ``repro.fl.engine.run_engine``),

plus a Fig. 3-style 4-point grid as one ``run_sweep`` batched call.
Competing variants share an ``interleaved_best_of`` timing loop (legacy
and engine back-to-back each rep, likewise the two sweep paths) so slow
drift in box load never reads as a path difference; each row is
best-of-``REPS`` after a warm-up run (jit caches hot), so the numbers
track steady-state orchestration cost, not compile time.

The local-step budget is 1 SGD step per epoch: the engine's advantage is the
orchestration it eliminates (per-edge dispatch, host-side batching, per-round
syncs), and heavier local compute is identical FLOPs on both paths — see
EXPERIMENTS.md §Perf for the step-budget sensitivity.

The JSON also records the kernel-plane coverage of the engine rows — which
round phases run as fused Pallas kernels under the resolved ``kernel_mode``
(``repro.kernels.fused_phase_coverage``), the ``padded_flop_frac``-style
column for the kernel plane.  On CPU ``auto`` resolves to ``xla`` and the
fraction is 0.0; on TPU/GPU the same rows report full fused coverage.

  PYTHONPATH=src python -m benchmarks.run --only engine --emit-json
"""
from __future__ import annotations

import dataclasses
import json

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import BHFLSimulator, run_sweep
from repro.kernels import fused_phase_coverage, resolve_kernel_mode

from .common import Csv, interleaved_best_of

T_ROUNDS = 20
KW = dict(n_train=2000, n_test=400, steps_per_epoch=1, normalize=True)
REPS = 3


def _setting():
    return dataclasses.replace(REDUCED, t_global_rounds=T_ROUNDS)


def _sim(**kw):
    return BHFLSimulator(_setting(), "hieavg", "temporary", "temporary",
                         **KW, **kw)


def kernel_plane_record(mode: str = "auto") -> dict:
    """The kernel-plane coverage block shared by the BENCH_*.json emitters:
    resolved mode, per-phase fused flags, and the fused fraction."""
    resolved = resolve_kernel_mode(mode)
    cov = fused_phase_coverage(mode)
    frac = sum(cov.values()) / len(cov) if cov else 0.0
    return {"kernel_mode": mode, "resolved": resolved,
            "fused_phases": cov,
            "fused_phase_frac": round(frac, 3)}


def main(emit_json: bool = True) -> dict:
    csv = Csv("bench_engine")
    kp = kernel_plane_record("auto")
    csv.row("path", "seconds", "rounds_per_sec", "fused_phase_frac")

    # head-to-head: legacy loop vs jitted engine, reps interleaved
    single = interleaved_best_of({
        "legacy_loop": lambda: _sim().run_legacy(),
        "jitted_engine": lambda: _sim().run(),
    }, REPS)
    t_legacy, t_engine = single["legacy_loop"], single["jitted_engine"]
    csv.row("legacy_loop", f"{t_legacy:.2f}", f"{T_ROUNDS / t_legacy:.2f}",
            "0.000")
    csv.row("jitted_engine", f"{t_engine:.2f}", f"{T_ROUNDS / t_engine:.2f}",
            f"{kp['fused_phase_frac']:.3f}")

    # Fig. 3-style grid: 2 straggler fractions x 2 seeds, one batched call
    overrides = [{"straggler_frac": f} for f in (0.2, 0.4)]
    seeds = (0, 1)
    n_pts = len(overrides) * len(seeds)

    def sweep_legacy():
        for ov in overrides:
            for seed in seeds:
                BHFLSimulator(dataclasses.replace(_setting(), **ov), "hieavg",
                              "temporary", "temporary", seed=seed,
                              **KW).run_legacy()

    sweep = interleaved_best_of({
        "legacy_4pt_sweep": sweep_legacy,
        "engine_4pt_sweep": lambda: run_sweep(
            _setting(), seeds=seeds, overrides=overrides, **KW),
    }, REPS)
    t_sweep_legacy = sweep["legacy_4pt_sweep"]
    t_sweep_engine = sweep["engine_4pt_sweep"]
    sweep_rounds = n_pts * T_ROUNDS
    csv.row("legacy_4pt_sweep", f"{t_sweep_legacy:.2f}",
            f"{sweep_rounds / t_sweep_legacy:.2f}", "0.000")
    csv.row("engine_4pt_sweep", f"{t_sweep_engine:.2f}",
            f"{sweep_rounds / t_sweep_engine:.2f}",
            f"{kp['fused_phase_frac']:.3f}")

    out = {
        "setting": "REDUCED",
        "n_edges": _setting().n_edges,
        "t_global_rounds": T_ROUNDS,
        "steps_per_epoch": KW["steps_per_epoch"],
        "reps": REPS,
        "timing": "interleaved_best_of",
        "kernel_plane": kp,
        "legacy_rounds_per_sec": round(T_ROUNDS / t_legacy, 3),
        "engine_rounds_per_sec": round(T_ROUNDS / t_engine, 3),
        "speedup": round(t_legacy / t_engine, 2),
        "sweep_points": n_pts,
        "sweep_legacy_rounds_per_sec": round(sweep_rounds / t_sweep_legacy, 3),
        "sweep_engine_rounds_per_sec": round(sweep_rounds / t_sweep_engine, 3),
        "sweep_speedup": round(t_sweep_legacy / t_sweep_engine, 2),
    }
    if emit_json:
        with open("BENCH_engine.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote BENCH_engine.json (speedup {out['speedup']}x, "
              f"sweep {out['sweep_speedup']}x)")
    csv.done()
    return out


if __name__ == "__main__":
    main()
