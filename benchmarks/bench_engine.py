"""Engine vs legacy-loop throughput — writes ``BENCH_engine.json``.

Measures rounds/sec for the same REDUCED (N=5 edges) deployment driven by

  * the legacy per-edge Python loop (``BHFLSimulator.run_legacy``), and
  * the fully-jitted batched engine (``BHFLSimulator.run`` →
    ``repro.fl.engine.run_engine``),

plus a Fig. 3-style 4-point grid as one ``run_sweep`` batched call.  Timings
are best-of-``REPS`` after a warm-up run (jit caches hot), so the numbers
track steady-state orchestration cost, not compile time.

The local-step budget is 1 SGD step per epoch: the engine's advantage is the
orchestration it eliminates (per-edge dispatch, host-side batching, per-round
syncs), and heavier local compute is identical FLOPs on both paths — see
EXPERIMENTS.md §Perf for the step-budget sensitivity.

  PYTHONPATH=src python -m benchmarks.run --only engine --emit-json
"""
from __future__ import annotations

import dataclasses
import json

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import BHFLSimulator, run_sweep

from .common import Csv, best_of

T_ROUNDS = 20
KW = dict(n_train=2000, n_test=400, steps_per_epoch=1, normalize=True)
REPS = 3


def _setting():
    return dataclasses.replace(REDUCED, t_global_rounds=T_ROUNDS)


def _sim(**kw):
    return BHFLSimulator(_setting(), "hieavg", "temporary", "temporary",
                         **KW, **kw)


def main(emit_json: bool = True) -> dict:
    csv = Csv("bench_engine")
    csv.row("path", "seconds", "rounds_per_sec")

    t_legacy = best_of(lambda: _sim().run_legacy(), REPS)
    csv.row("legacy_loop", f"{t_legacy:.2f}", f"{T_ROUNDS / t_legacy:.2f}")

    t_engine = best_of(lambda: _sim().run(), REPS)
    csv.row("jitted_engine", f"{t_engine:.2f}", f"{T_ROUNDS / t_engine:.2f}")

    # Fig. 3-style grid: 2 straggler fractions x 2 seeds, one batched call
    overrides = [{"straggler_frac": f} for f in (0.2, 0.4)]
    seeds = (0, 1)
    n_pts = len(overrides) * len(seeds)

    def sweep_legacy():
        for ov in overrides:
            for seed in seeds:
                BHFLSimulator(dataclasses.replace(_setting(), **ov), "hieavg",
                              "temporary", "temporary", seed=seed,
                              **KW).run_legacy()

    t_sweep_legacy = best_of(sweep_legacy, REPS)
    t_sweep_engine = best_of(lambda: run_sweep(
        _setting(), seeds=seeds, overrides=overrides, **KW), REPS)
    sweep_rounds = n_pts * T_ROUNDS
    csv.row("legacy_4pt_sweep", f"{t_sweep_legacy:.2f}",
            f"{sweep_rounds / t_sweep_legacy:.2f}")
    csv.row("engine_4pt_sweep", f"{t_sweep_engine:.2f}",
            f"{sweep_rounds / t_sweep_engine:.2f}")

    out = {
        "setting": "REDUCED",
        "n_edges": _setting().n_edges,
        "t_global_rounds": T_ROUNDS,
        "steps_per_epoch": KW["steps_per_epoch"],
        "reps": REPS,
        "legacy_rounds_per_sec": round(T_ROUNDS / t_legacy, 3),
        "engine_rounds_per_sec": round(T_ROUNDS / t_engine, 3),
        "speedup": round(t_legacy / t_engine, 2),
        "sweep_points": n_pts,
        "sweep_legacy_rounds_per_sec": round(sweep_rounds / t_sweep_legacy, 3),
        "sweep_engine_rounds_per_sec": round(sweep_rounds / t_sweep_engine, 3),
        "sweep_speedup": round(t_sweep_legacy / t_sweep_engine, 2),
    }
    if emit_json:
        with open("BENCH_engine.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote BENCH_engine.json (speedup {out['speedup']}x, "
              f"sweep {out['sweep_speedup']}x)")
    csv.done()
    return out


if __name__ == "__main__":
    main()
