"""Latency-fabric throughput — writes ``BENCH_latency.json``.

Measures points/sec for the fig7 grid (both panels: lp_device scaling +
consensus-multiplier × K — all shape- or data-changing latency knobs)
driven two ways:

  * ``legacy_loop`` — one ``BHFLSimulator.run_legacy`` per point: the
    pre-fabric way to measure a latency×K tradeoff empirically (a Python
    loop of standalone runs, no clock accounting),
  * ``fabric_sweep`` — the whole grid as ONE compiled padded sweep
    through ``plan_sweep``/``execute_plan`` (``run_sweep``), simulated
    clock trajectories included.

Timings are best-of-``REPS`` after a warm-up run (the shared ``best_of``
helper), like bench_engine/bench_sweep.  The budget is intentionally
small (T=10, 1 local step) so the numbers track orchestration overhead,
not training FLOPs.

  PYTHONPATH=src python -m benchmarks.run --only latency --emit-json
"""
from __future__ import annotations

import dataclasses
import json

from repro.configs.bhfl_cnn import REDUCED

from .common import Csv, best_of
from .fig7_latency import sweep_overrides

T_ROUNDS = 10
KW = dict(n_train=1500, n_test=300, steps_per_epoch=1, normalize=True)
REPS = 2


def _setting():
    return dataclasses.replace(REDUCED, t_global_rounds=T_ROUNDS)


def main(emit_json: bool = True) -> dict:
    from repro.fl import BHFLSimulator, run_sweep

    csv = Csv("bench_latency")
    csv.row("path", "seconds", "points_per_sec")
    overrides, _ = sweep_overrides()
    n_pts = len(overrides)

    def legacy_loop():
        for ov in overrides:
            BHFLSimulator(dataclasses.replace(_setting(), **ov),
                          "hieavg", "temporary", "temporary",
                          **KW).run_legacy()

    t_legacy = best_of(legacy_loop, REPS)
    csv.row("legacy_loop", f"{t_legacy:.2f}", f"{n_pts / t_legacy:.2f}")

    # max_buckets=1: this artifact's claim is the ONE-call sweep (E4);
    # bucketed throughput is bench_sweep's concern
    t_sweep = best_of(lambda: run_sweep(_setting(), overrides=overrides,
                                        max_buckets=1, **KW), REPS)
    csv.row("fabric_sweep", f"{t_sweep:.2f}", f"{n_pts / t_sweep:.2f}")

    out = {
        "setting": "REDUCED",
        "grid": "fig7 (both panels)",
        "points": n_pts,
        "t_global_rounds": T_ROUNDS,
        "steps_per_epoch": KW["steps_per_epoch"],
        "reps": REPS,
        "legacy_points_per_sec": round(n_pts / t_legacy, 3),
        "sweep_points_per_sec": round(n_pts / t_sweep, 3),
        "sweep_speedup_vs_legacy": round(t_legacy / t_sweep, 2),
    }
    if emit_json:
        with open("BENCH_latency.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote BENCH_latency.json (one-call sweep "
              f"{out['sweep_speedup_vs_legacy']}x vs legacy loop)")
    csv.done()
    return out


if __name__ == "__main__":
    main()
