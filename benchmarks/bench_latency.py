"""Latency-fabric throughput — writes ``BENCH_latency.json``.

Measures points/sec for the fig7 grid (all three panels: lp_device
scaling + consensus-multiplier × K + the consensus zoo — every shape- or
data-changing latency knob) driven two ways:

  * ``legacy_loop`` — one ``BHFLSimulator.run_legacy`` per point: the
    pre-fabric way to measure a latency×K tradeoff empirically (a Python
    loop of standalone runs, no clock accounting),
  * ``fabric_sweep`` — the whole grid as ONE compiled padded sweep
    through ``plan_sweep``/``execute_plan`` (``run_sweep``), simulated
    clock AND consensus-energy trajectories included.

The JSON also carries a ``consensus`` block: per zoo protocol, a
host-side Monte-Carlo chain replay (mean per-round latency/energy) next
to its closed-form expectations and their relative error — the bench-side
echo of the ``consensus_mc`` test pins.

Timings are best-of-``REPS`` after a warm-up run (the shared ``best_of``
helper), like bench_engine/bench_sweep.  The budget is intentionally
small (T=10, 1 local step) so the numbers track orchestration overhead,
not training FLOPs.  ``smoke=True`` (the ``--smoke`` flag, used by
tests/test_bench_emission.py) shrinks the grid/rounds/data so the whole
emission path runs in seconds.

  PYTHONPATH=src python -m benchmarks.run --only latency --emit-json
"""
from __future__ import annotations

import dataclasses
import json

from repro.configs.bhfl_cnn import REDUCED
from repro.core.consensus import CONSENSUS_MODELS, make_chain

from .common import Csv, best_of
from .fig7_latency import sweep_overrides

T_ROUNDS = 10
KW = dict(n_train=1500, n_test=300, steps_per_epoch=1, normalize=True)
REPS = 2
MC_ROUNDS = 200


def _setting(t_rounds: int = T_ROUNDS):
    return dataclasses.replace(REDUCED, t_global_rounds=t_rounds)


def _consensus_block(setting, mc_rounds: int) -> dict:
    """Per-protocol MC chain replay vs closed forms (host-side, no jit)."""
    out = {}
    for name, spec in CONSENSUS_MODELS.items():
        params = spec.make_params(setting.link_latency, setting.n_shards)
        chain = make_chain(name, setting.n_edges,
                           link_latency=setting.link_latency,
                           n_shards=setting.n_shards)
        for t in range(mc_rounds):
            chain.elect_leader()
            chain.commit_block(f"e@{t}", f"g@{t}")
        mc_lat = chain.clock / mc_rounds
        mc_en = chain.energy / mc_rounds
        want_lat = spec.expected_latency(params, setting.n_edges)
        want_en = spec.expected_energy(params, setting.n_edges)
        out[name] = {
            "mc_latency_s": round(mc_lat, 5),
            "expected_latency_s": round(want_lat, 5),
            "rel_err_latency": round(abs(mc_lat - want_lat) / want_lat, 4),
            "mc_energy_j": round(mc_en, 5),
            "expected_energy_j": round(want_en, 5),
            "rel_err_energy": round(abs(mc_en - want_en) / want_en, 4),
        }
    return out


def main(emit_json: bool = True, smoke: bool = False) -> dict:
    from repro.fl import BHFLSimulator, run_sweep

    t_rounds = 3 if smoke else T_ROUNDS
    kw = dict(KW, n_train=300, n_test=100) if smoke else KW
    reps = 1 if smoke else REPS
    mc_rounds = 50 if smoke else MC_ROUNDS

    csv = Csv("bench_latency")
    csv.row("path", "seconds", "points_per_sec")
    overrides, _, split_c = sweep_overrides()
    if smoke:
        # panel (a) head + the zoo points: one shape bucket, every protocol
        overrides = overrides[:1] + overrides[split_c:]
    n_pts = len(overrides)

    def legacy_loop():
        for ov in overrides:
            BHFLSimulator(dataclasses.replace(_setting(t_rounds), **ov),
                          "hieavg", "temporary", "temporary",
                          **kw).run_legacy()

    t_legacy = best_of(legacy_loop, reps)
    csv.row("legacy_loop", f"{t_legacy:.2f}", f"{n_pts / t_legacy:.2f}")

    # max_buckets=1: this artifact's claim is the ONE-call sweep (E4);
    # bucketed throughput is bench_sweep's concern
    t_sweep = best_of(lambda: run_sweep(_setting(t_rounds),
                                        overrides=overrides,
                                        max_buckets=1, **kw), reps)
    csv.row("fabric_sweep", f"{t_sweep:.2f}", f"{n_pts / t_sweep:.2f}")

    out = {
        "setting": "REDUCED",
        "grid": "fig7 (all panels, smoke)" if smoke else "fig7 (all panels)",
        "points": n_pts,
        "t_global_rounds": t_rounds,
        "steps_per_epoch": kw["steps_per_epoch"],
        "reps": reps,
        "legacy_points_per_sec": round(n_pts / t_legacy, 3),
        "sweep_points_per_sec": round(n_pts / t_sweep, 3),
        "sweep_speedup_vs_legacy": round(t_legacy / t_sweep, 2),
        "consensus": _consensus_block(_setting(t_rounds), mc_rounds),
    }
    if emit_json:
        with open("BENCH_latency.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote BENCH_latency.json (one-call sweep "
              f"{out['sweep_speedup_vs_legacy']}x vs legacy loop)")
    csv.done()
    return out


if __name__ == "__main__":
    main()
