"""Fig. 4 — data-distribution heterogeneity (classes per device) and
inconsistent numbers of local devices per edge.

Runs on the sweep fabric: the classes-per-device grid is one batched call;
the inconsistent-J comparison feeds the ragged per-edge device list through
the planner (one call per aggregator — the aggregator is a static program
branch, not sweep data)."""
from __future__ import annotations

from repro.fl import run_sweep

from .common import Csv, setting, sim_kwargs


def main() -> dict:
    out = {}
    csv = Csv("fig4_heterogeneity")
    csv.row("experiment", "value", "aggregator", "final_acc", "best_acc")

    classes = (1, 2, 4)
    sw = run_sweep(setting(),
                   overrides=[{"classes_per_device": c} for c in classes],
                   **sim_kwargs())
    for p, (ov, _seed) in enumerate(sw.points):
        acc = sw.accuracy[p]
        csv.row("non_iid_classes", ov["classes_per_device"], "hieavg",
                f"{acc[-1]:.4f}", f"{acc.max():.4f}")
        out[("classes", ov["classes_per_device"])] = acc

    # inconsistent J_i (Fig. 4b): HieAvg vs the benchmarks — the ragged
    # [3..7] device list rides through the planner's j_per_edge padding
    j_mix = [3, 4, 5, 6, 7]
    for agg in ("hieavg", "t_fedavg", "d_fedavg"):
        sw = run_sweep(setting(), overrides=[{"j_per_edge": j_mix}],
                       aggregator=agg, **sim_kwargs())
        acc = sw.accuracy[0]
        csv.row("inconsistent_J", "3-7", agg, f"{acc[-1]:.4f}",
                f"{acc.max():.4f}")
        out[("inconsistent", agg)] = acc
    csv.done()
    return out


if __name__ == "__main__":
    main()
