"""Fig. 4 — data-distribution heterogeneity (classes per device) and
inconsistent numbers of local devices per edge.

Runs on the fully-jitted batched engine: the classes-per-device sweep is
shape-preserving, so it executes as ONE ``run_sweep`` vmapped call; the
inconsistent-J comparison swaps aggregators (a static program branch) and
runs one compiled engine call each."""
from __future__ import annotations

from repro.fl import BHFLSimulator, run_sweep

from .common import Csv, setting, sim_kwargs


def main() -> dict:
    out = {}
    csv = Csv("fig4_heterogeneity")
    csv.row("experiment", "value", "aggregator", "final_acc", "best_acc")

    classes = (1, 2, 4)
    sw = run_sweep(setting(),
                   overrides=[{"classes_per_device": c} for c in classes],
                   **sim_kwargs())
    for p, (ov, _seed) in enumerate(sw.points):
        acc = sw.accuracy[p]
        csv.row("non_iid_classes", ov["classes_per_device"], "hieavg",
                f"{acc[-1]:.4f}", f"{acc.max():.4f}")
        out[("classes", ov["classes_per_device"])] = acc

    # inconsistent J_i (Fig. 4b): HieAvg vs the benchmarks
    j_mix = [3, 4, 5, 6, 7]
    for agg in ("hieavg", "t_fedavg", "d_fedavg"):
        r = BHFLSimulator(setting(), agg, "temporary", "temporary",
                          j_per_edge=j_mix, **sim_kwargs()).run()
        csv.row("inconsistent_J", "3-7", agg, f"{r.accuracy[-1]:.4f}",
                f"{r.accuracy.max():.4f}")
        out[("inconsistent", agg)] = r.accuracy
    csv.done()
    return out


if __name__ == "__main__":
    main()
