"""Fig. 4 — data-distribution heterogeneity (classes per device) and
inconsistent numbers of local devices per edge."""
from __future__ import annotations

from repro.fl import BHFLSimulator

from .common import Csv, setting, sim_kwargs


def main() -> dict:
    out = {}
    csv = Csv("fig4_heterogeneity")
    csv.row("experiment", "value", "aggregator", "final_acc", "best_acc")

    for classes in (1, 2, 4):
        s = setting(classes_per_device=classes)
        r = BHFLSimulator(s, "hieavg", "temporary", "temporary",
                          **sim_kwargs()).run()
        csv.row("non_iid_classes", classes, "hieavg",
                f"{r.accuracy[-1]:.4f}", f"{r.accuracy.max():.4f}")
        out[("classes", classes)] = r.accuracy

    # inconsistent J_i (Fig. 4b): HieAvg vs the benchmarks
    j_mix = [3, 4, 5, 6, 7]
    for agg in ("hieavg", "t_fedavg", "d_fedavg"):
        r = BHFLSimulator(setting(), agg, "temporary", "temporary",
                          j_per_edge=j_mix, **sim_kwargs()).run()
        csv.row("inconsistent_J", "3-7", agg, f"{r.accuracy[-1]:.4f}",
                f"{r.accuracy.max():.4f}")
        out[("inconsistent", agg)] = r.accuracy
    csv.done()
    return out


if __name__ == "__main__":
    main()
