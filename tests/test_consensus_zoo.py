"""The consensus zoo: pluggable protocols pinned by Monte-Carlo replay.

A consensus model is a discrete-event ``ConsensusChain`` replay plus a
closed-form expected-latency/energy pair (``repro.core.consensus``).  This
suite holds the two halves together (property-based MC pins at ≤5% relative
error, marker ``consensus_mc``), enforces the zoo-wide below-quorum raise,
and pins the sweep-fabric composition: ``consensus``/``n_shards`` are
data-batched fields, so mixed-protocol × aggregation × topology grids run
as ONE padded compiled call with per-point ``sim_clock``/``sim_energy``
parity against standalone runs — and the new energy axis is *bitwise* inert
on padded extents.
"""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.bhfl_cnn import REDUCED
from repro.core import consensus as zoo
from repro.core.blockchain import (RaftChain, RaftParams,
                                   expected_consensus_energy,
                                   expected_consensus_latency)
from repro.fl import BHFLSimulator, run_sweep
from repro.fl.engine import build_inputs, run_engine

TINY = dataclasses.replace(REDUCED, t_global_rounds=3, n_edges=3,
                           j_per_edge=3, image_hw=8)
KW = dict(n_train=300, n_test=100, steps_per_epoch=2)

MC_ROUNDS = 400     # elect+commit rounds per MC estimate (draws are iid)
PIN_RTOL = 0.05     # the acceptance criterion: closed form within 5% of MC


def _mc_round_costs(chain, rounds=MC_ROUNDS):
    """Mean per-round (latency s, energy J) over ``rounds`` elect+commit
    rounds — the exact sequence ``fl.engine.replay_chain`` drives."""
    t0, e0 = chain.clock, chain.energy
    for t in range(rounds):
        chain.elect_leader()
        chain.commit_block(f"edges@{t}", f"global@{t}")
    return (chain.clock - t0) / rounds, (chain.energy - e0) / rounds


def _kill_highest(chain, n_dead):
    """Fail the ``n_dead`` highest ids — the prefix alive-set the sharded
    closed forms assume (immaterial for raft/pofel)."""
    for i in range(chain.n - n_dead, chain.n):
        chain.fail_node(i)


# ------------------------------------------------- MC vs closed-form pins
@pytest.mark.consensus_mc
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(3, 9),
       dead=st.integers(0, 4), link=st.floats(0.01, 0.2),
       lo=st.floats(0.1, 0.3), w=st.floats(0.05, 0.3))
def test_raft_mc_pins_closed_forms(*, seed, n, dead, link, lo, w):
    dead = min(dead, (n - 1) // 2)          # stay at/above quorum
    params = RaftParams(link_latency=link, election_timeout=(lo, lo + w))
    chain = RaftChain(n, params, seed=seed)
    _kill_highest(chain, dead)
    lat, en = _mc_round_costs(chain)
    a = n - dead
    np.testing.assert_allclose(
        lat, expected_consensus_latency(params, n, a), rtol=PIN_RTOL)
    np.testing.assert_allclose(
        en, expected_consensus_energy(params, n, a), rtol=PIN_RTOL)


@pytest.mark.consensus_mc
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(3, 9),
       dead=st.integers(0, 4), eval_time=st.floats(0.02, 0.2),
       jitter=st.floats(0.05, 0.45), candidates=st.integers(1, 5))
def test_pofel_mc_pins_closed_forms(*, seed, n, dead, eval_time, jitter,
                                    candidates):
    dead = min(dead, (n - 1) // 2)
    params = zoo.PoFELParams(eval_time=eval_time, eval_jitter=jitter,
                             n_candidates=candidates)
    chain = zoo.PoFELChain(n, params, seed=seed)
    _kill_highest(chain, dead)
    lat, en = _mc_round_costs(chain)
    a = n - dead
    np.testing.assert_allclose(
        lat, zoo.expected_pofel_latency(params, n, a), rtol=PIN_RTOL)
    np.testing.assert_allclose(
        en, zoo.expected_pofel_energy(params, n, a), rtol=PIN_RTOL)


@pytest.mark.consensus_mc
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(3, 9),
       shards=st.integers(1, 4), dead=st.integers(0, 3),
       jitter=st.floats(0.05, 0.45))
def test_sharded_mc_pins_closed_forms(*, seed, n, shards, dead, jitter):
    """Per-shard quorum means a global majority is NOT always enough: when
    the closed forms return inf for the prefix alive-set, the chain must
    raise; otherwise the MC pins hold (energy is deterministic here)."""
    dead = min(dead, (n - 1) // 2)
    params = zoo.ShardedParams(n_shards=shards, intra_jitter=jitter)
    chain = zoo.ShardedChain(n, params, seed=seed)
    _kill_highest(chain, dead)
    a = n - dead
    want_lat = zoo.expected_sharded_latency(params, n, a)
    want_en = zoo.expected_sharded_energy(params, n, a)
    if not np.isfinite(want_lat):
        with pytest.raises(RuntimeError, match="no majority alive"):
            chain.elect_leader()
        return
    lat, en = _mc_round_costs(chain)
    np.testing.assert_allclose(lat, want_lat, rtol=PIN_RTOL)
    np.testing.assert_allclose(en, want_en, rtol=1e-6)


@pytest.mark.consensus_mc
def test_registry_builds_every_protocol_with_finite_expectations():
    for name, spec in zoo.CONSENSUS_MODELS.items():
        params = spec.make_params(0.07, 3)
        assert isinstance(params, spec.params_cls)
        chain = zoo.make_chain(name, 5, link_latency=0.07, n_shards=3)
        assert isinstance(chain, spec.chain_cls)
        assert np.isfinite(zoo.expected_round_latency(name, params, 5))
        assert np.isfinite(zoo.expected_round_energy(name, params, 5))
        # one full round works and accrues both cost axes
        chain.elect_leader()
        _, t = chain.commit_block("e", "g")
        assert t > 0 and chain.energy > 0 and chain.validate()


# -------------------------------------------------- below-quorum regression
@pytest.mark.parametrize("name", sorted(zoo.CONSENSUS_MODELS))
def test_below_quorum_raises_never_spins(name):
    """Zoo-wide PR 3 guarantee: losing the majority raises immediately from
    BOTH phases — no protocol may loop forever waiting for a quorum."""
    chain = zoo.make_chain(name, 5)
    for i in (2, 3, 4):          # alive prefix {0, 1} < the 3-node quorum
        chain.fail_node(i)
    with pytest.raises(RuntimeError, match="no majority alive"):
        chain.elect_leader()

    chain = zoo.make_chain(name, 5)
    chain.elect_leader()
    for i in (2, 3, 4):
        chain.fail_node(i)
    with pytest.raises(RuntimeError, match="no majority alive"):
        chain.commit_block("e", "g")

    # the closed forms agree: no finite expectation exists down there
    params = zoo.CONSENSUS_MODELS[name].make_params(0.05, 2)
    assert zoo.expected_round_latency(name, params, 5, 2) == float("inf")
    assert zoo.expected_round_energy(name, params, 5, 2) == float("inf")


def test_unknown_consensus_raises_naming_known_models():
    with pytest.raises(ValueError, match="nakamoto.*raft.*sharded"):
        zoo.make_chain("nakamoto", 5)
    with pytest.raises(ValueError, match="consensus model"):
        BHFLSimulator(dataclasses.replace(TINY, consensus="pow"),
                      "hieavg", "temporary", "temporary", **KW)


def test_wrong_params_class_raises():
    with pytest.raises(TypeError, match="PoFELParams"):
        zoo.make_chain("pofel", 5, params=RaftParams())


# --------------------------------------------------- sweep-field composition
def _check_point(sw, p, r):
    tv = int(sw.t_valid[p])
    np.testing.assert_allclose(sw.accuracy[p, :tv], r.accuracy, atol=1e-6)
    np.testing.assert_allclose(sw.sim_clock[p, :tv], r.sim_clock, rtol=1e-5)
    np.testing.assert_allclose(sw.sim_energy[p, :tv], r.sim_energy,
                               atol=1e-6)


def test_mixed_consensus_grid_matches_standalone_runs():
    """The acceptance criterion: a mixed raft/pofel/sharded grid is ONE
    compiled call — the protocol only changes the host-side chain replay —
    with per-point clock AND energy parity against standalone runs."""
    overrides = [{"consensus": "raft"}, {"consensus": "pofel"},
                 {"consensus": "sharded"},
                 {"consensus": "sharded", "n_shards": 3},
                 {"consensus": "pofel", "consensus_mult": 100.0}]
    sw = run_sweep(TINY, overrides=overrides, **KW)
    assert sw.sim_energy.shape == sw.sim_clock.shape
    for p, (ov, seed) in enumerate(sw.points):
        s = dataclasses.replace(TINY, **ov)
        r = BHFLSimulator(s, "hieavg", "temporary", "temporary", seed=seed,
                          **KW).run()
        _check_point(sw, p, r)
    # the protocols genuinely differ on the energy axis, and energy is a
    # strictly increasing cumulative cost for every one of them
    assert not np.allclose(sw.sim_energy[0], sw.sim_energy[1])
    for p in range(len(sw.points)):
        clock, en = sw.energy_trajectory(p)
        assert en[0] > 0 and np.all(np.diff(en) > 0)
        assert clock.shape == en.shape
    # consensus_mult scales the latency draws, NEVER the energy: points 1
    # and 4 replay the identical pofel chain
    np.testing.assert_array_equal(sw.sim_energy[4], sw.sim_energy[1])
    assert sw.sim_clock[4, -1] > sw.sim_clock[1, -1]


def test_consensus_composes_with_aggregation_switching():
    """consensus (data-batched) × aggregation (traced-switched) in one
    grid: per-point parity against standalone runs of the right
    aggregator, padded path."""
    overrides = [{"consensus": "pofel", "aggregation": "delayed_grad"},
                 {"consensus": "sharded", "aggregation": "hieavg"},
                 {"consensus": "raft", "aggregation": "delayed_grad"}]
    sw = run_sweep(TINY, overrides=overrides, **KW)
    for p, (ov, seed) in enumerate(sw.points):
        ov = dict(ov)
        agg = ov.pop("aggregation")
        s = dataclasses.replace(TINY, **ov)
        r = BHFLSimulator(s, agg, "temporary", "temporary", seed=seed,
                          **KW).run()
        _check_point(sw, p, r)


def test_mixed_consensus_bucketed_matches_single_bucket_and_standalone():
    """consensus × topology: shape-changing points bucket; bucketing stays
    invisible to the energy axis exactly like the clock."""
    overrides = [{"consensus": "pofel", "n_edges": 2},
                 {"consensus": "sharded"},
                 {"consensus": "raft", "k_edge_rounds": 1},
                 {"consensus": "pofel", "t_global_rounds": 2}]
    bucketed = run_sweep(TINY, overrides=overrides, max_buckets=3,
                         bucket_waste=1.0, **KW)
    single = run_sweep(TINY, overrides=overrides, max_buckets=1, **KW)
    np.testing.assert_allclose(bucketed.sim_clock, single.sim_clock,
                               rtol=1e-5)
    np.testing.assert_allclose(bucketed.sim_energy, single.sim_energy,
                               atol=1e-6)
    for p, (ov, seed) in enumerate(bucketed.points):
        s = dataclasses.replace(TINY, **ov)
        r = BHFLSimulator(s, "hieavg", "temporary", "temporary", seed=seed,
                          **KW).run()
        _check_point(bucketed, p, r)
    # ragged rounds: the energy tail freezes at the final valid value
    tv = int(bucketed.t_valid[3])
    assert tv == 2
    np.testing.assert_array_equal(
        bucketed.sim_energy[3, tv:],
        np.repeat(bucketed.sim_energy[3, tv - 1],
                  bucketed.sim_energy.shape[1] - tv))


# --------------------------------------------------- energy-axis inertness
def test_energy_axis_padding_is_bitwise_inert():
    """Padded rounds contribute EXACTLY zero energy: the input plane
    carries 0.0 past t_valid, and the scan carry passes through — padded
    and unpadded runs agree bitwise, with the tail frozen."""
    sim_a = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    sim_b = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    inp = build_inputs(sim_a)
    pad = build_inputs(sim_b, t_max=5, k_max=4, n_max=5, j_max=6,
                       steps_max=4)
    T = TINY.t_global_rounds
    np.testing.assert_array_equal(np.asarray(pad.cons_energy)[T:], 0.0)
    np.testing.assert_array_equal(np.asarray(pad.cons_energy)[:T],
                                  np.asarray(inp.cons_energy))
    ea = np.asarray(run_engine(inp)[4])
    eb = np.asarray(run_engine(pad)[4])
    np.testing.assert_array_equal(eb[:T], ea)
    np.testing.assert_array_equal(eb[T:], np.repeat(eb[T - 1], 5 - T))


def test_consensus_mult_scales_the_clock_never_the_energy():
    base = BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                         **KW).run()
    mult = BHFLSimulator(dataclasses.replace(TINY, consensus_mult=100.0),
                         "hieavg", "temporary", "temporary", **KW).run()
    np.testing.assert_array_equal(mult.sim_energy, base.sim_energy)
    assert mult.sim_clock[-1] > base.sim_clock[-1]
