"""Benchmark-emission smoke: the bench harnesses run in-test.

``benchmarks.run --only latency --emit-json --smoke`` (and the chaos
plane's ``--only faults``) must execute end to end at a seconds-scale
budget and emit schema-valid ``BENCH_latency.json`` /
``BENCH_faults.json`` — so the artifact paths can't rot silently between
releases.
"""
import json
import sys

import numpy as np
import pytest

from benchmarks import run as bench_run
from benchmarks.fig7_latency import ZOO_POINTS, sweep_overrides


def test_fig7_grid_is_three_panels():
    ovs, split_b, split_c = sweep_overrides()
    assert 0 < split_b < split_c < len(ovs)
    assert ovs[split_c:] == [dict(p) for p in ZOO_POINTS]
    assert {p["consensus"] for p in ZOO_POINTS} == {"raft", "pofel",
                                                    "sharded"}


def test_latency_bench_smoke_emits_schema_valid_json(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    monkeypatch.setattr(sys, "argv", ["run", "--only", "latency",
                                      "--emit-json", "--smoke"])
    bench_run.main()

    data = json.loads((tmp_path / "BENCH_latency.json").read_text())
    for key in ("setting", "grid", "points", "t_global_rounds",
                "steps_per_epoch", "reps", "legacy_points_per_sec",
                "sweep_points_per_sec", "sweep_speedup_vs_legacy",
                "consensus"):
        assert key in data, key
    assert data["setting"] == "REDUCED"
    assert data["points"] >= 1 and data["t_global_rounds"] >= 1
    for key in ("legacy_points_per_sec", "sweep_points_per_sec",
                "sweep_speedup_vs_legacy"):
        assert np.isfinite(data[key]) and data[key] > 0, key

    cons = data["consensus"]
    assert set(cons) == {"raft", "pofel", "sharded"}
    for name, row in cons.items():
        for key in ("mc_latency_s", "expected_latency_s", "mc_energy_j",
                    "expected_energy_j"):
            assert np.isfinite(row[key]) and row[key] > 0, (name, key)
        # smoke-budget MC (50 rounds): loose sanity, the real ≤5% pin is
        # the consensus_mc suite's job
        assert row["rel_err_latency"] <= 0.25, name
        assert row["rel_err_energy"] <= 0.25, name


@pytest.mark.chaos
def test_faults_bench_smoke_emits_schema_valid_json(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    monkeypatch.setattr(sys, "argv", ["run", "--only", "faults",
                                      "--emit-json", "--smoke"])
    bench_run.main()

    data = json.loads((tmp_path / "BENCH_faults.json").read_text())
    for key in ("setting", "t_global_rounds", "points", "buckets",
                "seconds", "edge_recover_rate", "val_recover_rate",
                "max_stall_rounds", "protocols", "curves"):
        assert key in data, key
    assert data["setting"] == "REDUCED"
    # the acceptance criterion: the whole fault_rate x consensus grid runs
    # as ONE padded sweep call
    assert data["buckets"] == 1
    assert data["points"] >= 6
    assert set(data["protocols"]) == {"raft", "pofel", "sharded"}
    for proto in data["protocols"]:
        curve = data["curves"][proto]
        assert curve["edge_fail"] and curve["val_fail"], proto
        rates = [r["rate"] for r in curve["edge_fail"]]
        assert rates == sorted(rates) and rates[0] == 0.0
        for row in curve["edge_fail"] + curve["val_fail"]:
            for key in ("rate", "final_acc", "acc_drop", "final_clock_s"):
                assert np.isfinite(row[key]), (proto, key)
            assert 0.0 <= row["final_acc"] <= 1.0
        # the clean baseline defines drop=0 for its own protocol
        assert curve["edge_fail"][0]["acc_drop"] == 0.0
