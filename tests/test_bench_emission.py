"""Benchmark-emission smoke: the latency bench harness runs in-test.

``benchmarks.run --only latency --emit-json --smoke`` must execute end to
end at a seconds-scale budget and emit a schema-valid
``BENCH_latency.json`` — including the consensus block the zoo added —
so the artifact path can't rot silently between releases.
"""
import json
import sys

import numpy as np

from benchmarks import run as bench_run
from benchmarks.fig7_latency import ZOO_POINTS, sweep_overrides


def test_fig7_grid_is_three_panels():
    ovs, split_b, split_c = sweep_overrides()
    assert 0 < split_b < split_c < len(ovs)
    assert ovs[split_c:] == [dict(p) for p in ZOO_POINTS]
    assert {p["consensus"] for p in ZOO_POINTS} == {"raft", "pofel",
                                                    "sharded"}


def test_latency_bench_smoke_emits_schema_valid_json(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    monkeypatch.setattr(sys, "argv", ["run", "--only", "latency",
                                      "--emit-json", "--smoke"])
    bench_run.main()

    data = json.loads((tmp_path / "BENCH_latency.json").read_text())
    for key in ("setting", "grid", "points", "t_global_rounds",
                "steps_per_epoch", "reps", "legacy_points_per_sec",
                "sweep_points_per_sec", "sweep_speedup_vs_legacy",
                "consensus"):
        assert key in data, key
    assert data["setting"] == "REDUCED"
    assert data["points"] >= 1 and data["t_global_rounds"] >= 1
    for key in ("legacy_points_per_sec", "sweep_points_per_sec",
                "sweep_speedup_vs_legacy"):
        assert np.isfinite(data[key]) and data[key] > 0, key

    cons = data["consensus"]
    assert set(cons) == {"raft", "pofel", "sharded"}
    for name, row in cons.items():
        for key in ("mc_latency_s", "expected_latency_s", "mc_energy_j",
                    "expected_energy_j"):
            assert np.isfinite(row[key]) and row[key] > 0, (name, key)
        # smoke-budget MC (50 rounds): loose sanity, the real ≤5% pin is
        # the consensus_mc suite's job
        assert row["rel_err_latency"] <= 0.25, name
        assert row["rel_err_energy"] <= 0.25, name
