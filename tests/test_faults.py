"""Chaos-plane pins: fault schedules, churn replay, quorum stall, resume.

The ``chaos`` marker mirrors ``kernel_oracle``/``consensus_mc``: the whole
file also runs in tier-1, and the CI fault-injection job re-runs it alone
(``-m chaos``) as the focused signal when a fault-plane change breaks an
invariant.  Pinned contracts:

  * schedule compilation — determinism, shapes, zero-rate inertness,
    Markov stationarity, exact burst sizes;
  * ``fail_leader_at`` reproduces bitwise through the one-event schedule
    path, with NO simulator-state mutation (the replay-mutation bug);
  * ``recover_node`` is wired: fail→recover restores quorum and the
    closed-form ``n_alive`` latency/energy track the replay, all three
    protocols;
  * below-quorum mid-run: bounded stall-then-raise, with
    ``max_stall_rounds=0`` reproducing the immediate raise;
  * checkpoint crash safety + killed-run resume parity (bitwise).
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.bhfl_cnn import REDUCED
from repro.core.consensus import CONSENSUS_MODELS, make_chain
from repro.fl import BHFLSimulator, FaultSpec, compile_schedule, run_sweep
from repro.fl import faults as faults_mod

pytestmark = pytest.mark.chaos

TINY = dataclasses.replace(REDUCED, t_global_rounds=4, n_edges=3,
                           j_per_edge=3, image_hw=8)
KW = dict(n_train=300, n_test=100, steps_per_epoch=2)


# ------------------------------------------------------- schedule compiler
def test_zero_spec_is_inert_and_validated():
    sc = compile_schedule(FaultSpec(), t_rounds=6, k_rounds=2, n_edges=4,
                          j_per_edge=[3, 3, 3, 3], seed=0)
    assert sc.inert
    assert sc.edge_down.shape == (6, 4)
    assert sc.val_down.shape == (6, 1, 4)       # S=0 -> one attempt tick
    assert sc.dev_drop.shape == (12, 4, 3)
    assert sc.edge_msg_drop.shape == (6, 4)
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(edge_fail_rate=1.5)
    with pytest.raises(ValueError, match="max_stall_rounds"):
        FaultSpec(max_stall_rounds=-1)
    with pytest.raises(ValueError, match="leader_crash_round"):
        FaultSpec(leader_crash_round=0)


def test_schedule_is_deterministic_per_seed():
    spec = FaultSpec(edge_fail_rate=0.3, edge_recover_rate=0.5,
                     val_fail_rate=0.2, val_recover_rate=0.6,
                     burst_prob=0.4, msg_loss_prob=0.1, max_stall_rounds=2)
    kw = dict(t_rounds=8, k_rounds=2, n_edges=4, j_per_edge=[2, 3, 4, 3])
    a = compile_schedule(spec, seed=7, **kw)
    b = compile_schedule(spec, seed=7, **kw)
    c = compile_schedule(spec, seed=8, **kw)
    for f in ("edge_down", "val_down", "dev_drop", "edge_msg_drop"):
        assert (getattr(a, f) == getattr(b, f)).all(), f
    assert any((getattr(a, f) != getattr(c, f)).any()
               for f in ("edge_down", "val_down", "dev_drop",
                         "edge_msg_drop"))
    assert a.val_down.shape == (8, 3, 4)        # [T, S+1, N]


def test_markov_stationary_down_fraction():
    # two-state chain: stationary P[down] = f / (f + r)
    sc = compile_schedule(
        FaultSpec(edge_fail_rate=0.3, edge_recover_rate=0.5),
        t_rounds=4000, k_rounds=1, n_edges=4, j_per_edge=[2] * 4, seed=0)
    assert abs(sc.edge_down.mean() - 0.375) < 0.03


def test_burst_takes_exact_fraction_of_real_devices():
    j_per_edge = [3, 5, 2]
    sc = compile_schedule(
        FaultSpec(burst_prob=1.0, burst_frac=0.5),
        t_rounds=5, k_rounds=2, n_edges=3, j_per_edge=j_per_edge, seed=1)
    J = max(j_per_edge)
    for e, j_e in enumerate(j_per_edge):
        want = int(np.ceil(0.5 * j_e))
        per_round = sc.dev_drop[:, e, :].sum(axis=1)
        assert (per_round == want).all(), (e, per_round)
        # never drops a padded slot
        assert not sc.dev_drop[:, e, j_e:J].any()
        # a burst spans the whole global round (both K edge rounds)
        assert (sc.dev_drop[0::2, e] == sc.dev_drop[1::2, e]).all()


# ----------------------------------------- leader-crash drill (satellite 1)
def test_fail_leader_is_a_one_event_schedule_bitwise():
    """fail_leader_at=t and FaultSpec(leader_crash_round=t) are the same
    schedule — and neither consumes any fault-stream draws."""
    r1 = BHFLSimulator(TINY, fail_leader_at=2, **KW).run()
    r2 = BHFLSimulator(TINY, faults=FaultSpec(leader_crash_round=2),
                       **KW).run()
    assert (r1.accuracy == r2.accuracy).all()
    assert (r1.sim_clock == r2.sim_clock).all()
    assert (r1.sim_energy == r2.sim_energy).all()


def test_failover_replay_never_mutates_simulator_state():
    sim = BHFLSimulator(TINY, fail_leader_at=2, **KW)
    masks_before = sim.edge_masks.copy()
    r1 = sim.run()
    assert (sim.edge_masks == masks_before).all(), \
        "replay_chain wrote the failover into sim.edge_masks"
    r2 = sim.run()   # repeated run: bitwise repeatable under leader crash
    assert (r1.accuracy == r2.accuracy).all()
    assert (sim.edge_masks == masks_before).all()
    assert sim.chain.alive.sum() == sim.N - 1   # the one crash, applied once


def test_legacy_failover_leaves_masks_pristine():
    sim = BHFLSimulator(TINY, fail_leader_at=2, **KW)
    masks_before = sim.edge_masks.copy()
    sim.run_legacy()
    assert (sim.edge_masks == masks_before).all()


# ------------------------------------------- recover_node (satellite 2)
@pytest.mark.parametrize("proto", sorted(CONSENSUS_MODELS))
def test_recover_restores_quorum_and_closed_forms_track(proto):
    """fail→recover cycle: quorum is lost, recover_node restores it, and
    the closed-form n_alive latency/energy track the MC replay in every
    regime (all-up, degraded-but-quorate, recovered)."""
    N, rounds = 5, 300
    spec = CONSENSUS_MODELS[proto]
    params = spec.make_params(0.05, 2)

    def mc(chain, n):
        c0, e0 = chain.clock, chain.energy
        for t in range(n):
            chain.elect_leader()
            chain.commit_block(f"e@{t}", f"g@{t}")
        return (chain.clock - c0) / n, (chain.energy - e0) / n

    chain = make_chain(proto, N, link_latency=0.05, n_shards=2, seed=0)
    lat_up, en_up = mc(chain, rounds)
    assert abs(lat_up - spec.expected_latency(params, N)) \
        / spec.expected_latency(params, N) < 0.1
    assert abs(en_up - spec.expected_energy(params, N)) \
        / spec.expected_energy(params, N) < 0.1

    # fail the highest id (the closed forms' prefix-alive convention):
    # still quorate at 4/5 — latency/energy shift to the n_alive=4 forms
    chain.fail_node(N - 1)
    lat_deg, en_deg = mc(chain, rounds)
    want_lat = spec.expected_latency(params, N, 4)
    want_en = spec.expected_energy(params, N, 4)
    assert abs(lat_deg - want_lat) / want_lat < 0.1
    assert abs(en_deg - want_en) / want_en < 0.1

    # lose quorum outright, then recover: recover_node restores service
    chain.fail_node(N - 2)
    chain.fail_node(N - 3)
    with pytest.raises(RuntimeError, match="majority"):
        chain.elect_leader()
    for i in (N - 1, N - 2, N - 3):
        chain.recover_node(i)
    assert chain.n_alive() == N
    lat_rec, en_rec = mc(chain, rounds)
    assert abs(lat_rec - spec.expected_latency(params, N)) \
        / spec.expected_latency(params, N) < 0.1
    assert abs(en_rec - spec.expected_energy(params, N)) \
        / spec.expected_energy(params, N) < 0.1


def test_replay_tracks_validator_churn_closed_forms():
    """Engine-path cons_energy varies over rounds under churn, matching
    the chain's own per-round energy (the alive count moved)."""
    FT = dataclasses.replace(TINY, val_fail_rate=0.4, val_recover_rate=0.6,
                             max_stall_rounds=4)
    sim = BHFLSimulator(FT, **KW)
    r = sim.run()
    per_round = np.diff(np.concatenate([[0.0], r.sim_energy]))
    assert len(set(np.round(per_round, 6))) > 1, \
        "validator churn should modulate per-round consensus energy"


# --------------------------------------- quorum stall policy (satellite 3)
@pytest.mark.parametrize("proto", sorted(CONSENSUS_MODELS))
def test_mid_run_below_quorum_stalls_then_raises(proto):
    """Crash validators past majority mid-training: max_stall_rounds=0
    raises immediately (today's semantics); a stall budget with no
    recovery process raises only after the budget, with the backoff
    visible in the error-free rounds' clock."""
    setting = dataclasses.replace(TINY, consensus=proto)
    # permanent validator outage: fail, never recover -> quorum eventually
    # lost for good (edge_fail also fails the chain node each round)
    dead = dataclasses.replace(setting, edge_fail_rate=0.9,
                               edge_recover_rate=0.0)
    with pytest.raises(RuntimeError, match="majority|quorum|no live"):
        BHFLSimulator(dead, **KW).run()

    stalled = dataclasses.replace(dead, max_stall_rounds=2)
    with pytest.raises(RuntimeError, match="stalled below quorum"):
        BHFLSimulator(stalled, **KW).run()


def test_stall_backoff_lands_in_the_traced_clock():
    """A transient quorum loss that recovers mid-stall costs exactly the
    exponential backoff in the consensus draw (stalled_round), and the
    engine clock accounts it as C2 stall."""
    chain = make_chain("raft", 3, link_latency=0.05, n_shards=2, seed=0)
    spec = FaultSpec(max_stall_rounds=3, stall_backoff=0.5)
    sched = compile_schedule(spec, t_rounds=2, k_rounds=1, n_edges=3,
                             j_per_edge=[1, 1, 1], seed=0)
    # attempts 0 and 1 of round 1 are below quorum; attempt 2 recovers
    sched.val_down[0, 0] = [True, True, False]
    sched.val_down[0, 1] = [True, True, False]
    sched.val_down[0, 2] = [False, False, False]
    elapsed, energy, attempts, _ = faults_mod.stalled_round(chain, 1, sched)
    assert attempts == 2
    # two failed attempts: 0.5 * 2**0 + 0.5 * 2**1 = 1.5 s of backoff
    chain2 = make_chain("raft", 3, link_latency=0.05, n_shards=2, seed=0)
    clean, _, _, _ = faults_mod.stalled_round(
        chain2, 1, compile_schedule(spec, t_rounds=2, k_rounds=1,
                                    n_edges=3, j_per_edge=[1, 1, 1], seed=0))
    assert elapsed == pytest.approx(clean + 1.5)


# ------------------------------------- checkpoint crash safety (satellite 4)
def test_ckpt_writer_killed_between_tmp_and_rename(tmp_path, monkeypatch):
    d = str(tmp_path / "ck")
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    ckpt.save_checkpoint(d, 1, tree, metadata={"t": 1})

    real_replace = os.replace

    def killed(src, dst):
        raise KeyboardInterrupt("writer killed between tmp-write and rename")

    monkeypatch.setattr(ckpt.os, "replace", killed)
    with pytest.raises(KeyboardInterrupt):
        ckpt.save_checkpoint(d, 2, {"w": tree["w"] * 2}, metadata={"t": 2})
    monkeypatch.setattr(ckpt.os, "replace", real_replace)

    # the interrupted step never became visible; the prior one survives
    assert ckpt.latest_step(d) == 1
    restored, meta = ckpt.restore_checkpoint(d, like=tree)
    assert (restored["w"] == tree["w"]).all()
    assert meta == {"t": 1}


# --------------------------------------------- resumable runs (tentpole)
def _fresh_sim():
    return BHFLSimulator(TINY, fail_leader_at=2, **KW)


def test_killed_run_resumes_bitwise(tmp_path):
    straight = _fresh_sim().run_checkpointed(str(tmp_path / "a"), every=1)

    # run to completion in dir b, then simulate a kill after round 2 by
    # deleting the later checkpoints; a fresh simulator must resume from
    # the survivor and finish bitwise-identically
    _fresh_sim().run_checkpointed(str(tmp_path / "b"), every=1)
    for t in range(3, TINY.t_global_rounds + 1):
        os.remove(tmp_path / "b" / f"step_{t:08d}.npz")
    assert ckpt.latest_step(str(tmp_path / "b")) == 2
    resumed = _fresh_sim().run_checkpointed(str(tmp_path / "b"), every=1)

    assert (resumed.accuracy == straight.accuracy).all()
    assert (resumed.sim_clock == straight.sim_clock).all()
    assert (resumed.loss == straight.loss).all()
    assert (resumed.sim_energy == straight.sim_energy).all()


def test_checkpointed_matches_plain_run():
    plain = _fresh_sim().run()
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        chunked = _fresh_sim().run_checkpointed(d, every=2)
    np.testing.assert_allclose(chunked.accuracy, plain.accuracy,
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(chunked.sim_clock, plain.sim_clock,
                               rtol=1e-6)


# ---------------------------------------------------- sweep fabric parity
def test_fault_fields_batch_in_one_sweep_call():
    """A fault-rate x consensus grid is data-batched: the padded sweep
    reproduces each point's standalone engine run bitwise."""
    overrides = [
        {"consensus": "raft", "edge_fail_rate": 0.0},
        {"consensus": "raft", "edge_fail_rate": 0.4,
         "edge_recover_rate": 0.5},
        {"consensus": "pofel", "val_fail_rate": 0.25,
         "val_recover_rate": 0.9, "max_stall_rounds": 5},
        # sharded is quorum-fragile (a 1-node shard below quorum can't be
        # stalled through) — exercise it on the chain-free fault axes
        {"consensus": "sharded", "burst_prob": 0.5, "burst_frac": 0.5,
         "msg_loss_prob": 0.1},
    ]
    res = run_sweep(TINY, overrides=overrides, **KW)
    for p, (ov, seed) in enumerate(res.points):
        alone = BHFLSimulator(dataclasses.replace(TINY, **ov),
                              seed=seed, **KW).run()
        np.testing.assert_allclose(res.accuracy[p], alone.accuracy,
                                   atol=1e-6, err_msg=str(ov))
        np.testing.assert_allclose(res.sim_clock[p], alone.sim_clock,
                                   rtol=1e-5)
