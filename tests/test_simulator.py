"""End-to-end BHFL simulator behaviour (integration tests, small budgets)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.bhfl_cnn import BHFLSetting, REDUCED
from repro.fl import BHFLSimulator

TINY = dataclasses.replace(REDUCED, t_global_rounds=4, n_edges=3,
                           j_per_edge=3, image_hw=8)
KW = dict(n_train=300, n_test=100, steps_per_epoch=2)


def test_simulator_runs_and_commits_blocks():
    sim = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    r = sim.run()
    assert len(r.accuracy) == 4
    assert r.blocks == 4            # one block per global round
    assert r.chain_valid
    assert np.all(np.isfinite(r.loss))
    assert r.sim_latency > 0


@pytest.mark.parametrize("agg", ["t_fedavg", "d_fedavg", "fedavg"])
def test_all_aggregators_run(agg):
    strag = "none" if agg == "fedavg" else "temporary"
    r = BHFLSimulator(TINY, agg, strag, strag, **KW).run()
    assert np.all(np.isfinite(r.accuracy))


def test_loss_decreases_over_training():
    s = dataclasses.replace(TINY, t_global_rounds=8)
    r = BHFLSimulator(s, "hieavg", "none", "none", **KW).run()
    assert r.loss[-1] < r.loss[0]


def test_inconsistent_j_per_edge():
    """Fig. 4b: edges may host different numbers of devices."""
    sim = BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                        j_per_edge=[2, 3, 4], **KW)
    r = sim.run()
    assert sim.D == 9
    assert np.all(np.isfinite(r.accuracy))


def test_same_seed_reproducible():
    a = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW).run()
    b = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW).run()
    np.testing.assert_allclose(a.accuracy, b.accuracy)


def test_straggler_masks_respect_fraction():
    s = dataclasses.replace(REDUCED, t_global_rounds=3,
                            permanent_stop_round=1)
    sim = BHFLSimulator(s, "hieavg", "permanent", "permanent",
                        n_train=300, n_test=50, steps_per_epoch=1)
    # 20% of 5 devices = 1 straggler per edge after stop_round
    m = sim.dev_masks[0]
    assert (~m[-1]).sum() == 1
    assert (~sim.edge_masks[-1]).sum() == 1


# -------------------------------------------- heterogeneous device clocks
def test_device_rates_unit_is_bitwise_the_homogeneous_fleet():
    """``device_rates=1`` everywhere must be the exact homogeneous draw —
    the multiplier is applied, not re-sampled."""
    from repro.fl import build_inputs

    a = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    b = BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                      device_rates=[1.0] * 9, **KW)
    np.testing.assert_array_equal(np.asarray(build_inputs(b).dev_time),
                                  np.asarray(build_inputs(a).dev_time))


def test_device_rates_slow_device_is_capped_at_the_deadline():
    """A 100x-slow device must hit the per-round submission deadline
    (deadline-based aggregation) while every other device's draw is
    untouched, and the simulated clock must slow down accordingly."""
    from repro.core.latency import device_deadline
    from repro.fl import build_inputs

    rates = [1.0] * 9
    rates[0] = 100.0          # device 0 = edge 0, slot 0
    a = BHFLSimulator(TINY, "hieavg", "none", "none", **KW)
    b = BHFLSimulator(TINY, "hieavg", "none", "none",
                      device_rates=rates, **KW)
    ta = np.asarray(build_inputs(a).dev_time)   # [T, K, N, J]
    tb = np.asarray(build_inputs(b).dev_time)
    np.testing.assert_array_equal(tb[:, :, 1:, :], ta[:, :, 1:, :])
    np.testing.assert_array_equal(tb[:, :, 0, 1:], ta[:, :, 0, 1:])
    np.testing.assert_allclose(tb[:, :, 0, 0], device_deadline(b.lat),
                               rtol=1e-6)
    ra, rb = a.run(), b.run()
    # the *empirical* simulated clock slows down (sim_latency is the
    # Sec. 5 expectation model, which ignores rate_mult by design)
    assert rb.sim_clock[-1] > ra.sim_clock[-1]


def test_device_rates_validation():
    with pytest.raises(ValueError, match="every device"):
        BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                      device_rates=[1.0, 2.0], **KW)
    with pytest.raises(ValueError, match="positive"):
        BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                      device_rates=[1.0] * 8 + [-1.0], **KW)


def test_leader_failure_resilience():
    """The paper's single-point-of-failure claim: the Raft consortium
    re-elects after a leader crash and training finishes all rounds."""
    s = dataclasses.replace(TINY, t_global_rounds=6)
    sim = BHFLSimulator(s, "hieavg", "temporary", "temporary",
                        normalize=True, fail_leader_at=3, **KW)
    r = sim.run()
    assert len(r.accuracy) == 6          # all rounds completed
    assert r.blocks == 6                 # a block per round despite the crash
    assert r.chain_valid
    assert int(sim.chain.alive.sum()) == sim.N - 1
    assert sim.chain.leader is not None
    assert sim.chain.alive[sim.chain.leader]
