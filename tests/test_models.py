"""Per-architecture smoke tests + decode/train consistency.

Every assigned architecture instantiates its REDUCED variant, runs one
forward/train step on CPU, and asserts output shapes + no NaNs.  For every
cached-decode family we additionally check that teacher-forced step-by-step
decode reproduces the full-sequence forward logits — the strongest cheap
correctness invariant for KV caches, rings, MLA latents and SSM states.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.launch.inputs import _memory_shape
from repro.models import (cache_specs, count_params, forward_train,
                          init_from_specs, loss_fn, param_specs, prefill,
                          decode_step)

B, S = 2, 24


def setup_arch(arch):
    cfg = get_smoke(arch)
    params = init_from_specs(param_specs(cfg), jax.random.key(0))
    toks = (jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
            .astype(jnp.int32))
    ms = _memory_shape(cfg)
    mem = (0.1 * jax.random.normal(jax.random.key(2), (B,) + ms,
                                   jnp.float32) if ms else None)
    return cfg, params, toks, mem


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg, params, toks, mem = setup_arch(arch)
    logits, aux = forward_train(params, toks, cfg, memory_embeds=mem)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg, params, toks, mem = setup_arch(arch)
    loss, grads = jax.value_and_grad(loss_fn)(params, toks, toks, cfg,
                                              memory_embeds=mem)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(not bool(jnp.isnan(g).any()) for g in flat)
    new = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = loss_fn(new, toks, toks, cfg, memory_embeds=mem)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode == full-sequence forward (per position)."""
    cfg, params, toks, mem = setup_arch(arch)
    full_logits, _ = forward_train(params, toks, cfg, memory_embeds=mem)

    caches = init_from_specs(cache_specs(cfg, B, S, dtype=jnp.float32),
                             jax.random.key(3))
    split = S // 2
    lg, caches = prefill(params, toks[:, :split], cfg, caches,
                         memory_embeds=mem)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full_logits[:, split - 1]),
                               rtol=5e-2, atol=5e-3)
    for t in range(split, S):
        lg, caches = decode_step(params, toks[:, t:t + 1],
                                 jnp.asarray(t, jnp.int32), cfg, caches,
                                 memory=mem)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, t]),
            rtol=5e-2, atol=5e-3,
            err_msg=f"{arch}: decode diverges at position {t}")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exactness(arch):
    """The FULL config carries the exact assigned hyperparameters."""
    spec = {
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "mamba2-130m": (24, 768, 1, 1, 0, 50280),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == spec, (arch, got, spec)


def test_moe_param_count_matches_grok():
    cfg = get_config("grok-1-314b")
    n = count_params(param_specs(cfg))
    assert 300e9 < n < 330e9, n


def test_sliding_window_cache_is_ring():
    cfg = get_smoke("h2o-danube-1.8b")           # window 16
    cs = cache_specs(cfg, B, 64, dtype=jnp.float32)
    k_spec = jax.tree.leaves(cs)[0]
    assert k_spec.shape[-3] == 16, "ring cache must be window-sized"


def test_mla_cache_is_compressed():
    cfg = get_smoke("minicpm3-4b")
    cs = cache_specs(cfg, B, 32, dtype=jnp.float32)
    leaf_names = set()
    jax.tree_util.tree_map_with_path(
        lambda p, v: leaf_names.add(p[-1].key), cs)
    assert "c_kv" in leaf_names and "k" not in leaf_names


def test_ssm_cache_constant_size():
    cfg = get_smoke("mamba2-130m")
    c32 = cache_specs(cfg, B, 32, dtype=jnp.float32)
    c64k = cache_specs(cfg, B, 65536, dtype=jnp.float32)
    s32 = [s.shape for s in jax.tree.leaves(c32)]
    s64 = [s.shape for s in jax.tree.leaves(c64k)]
    assert s32 == s64, "SSM state must be O(1) in context length"
