"""Regression tests for the §Perf optimizations — each must preserve exact
semantics (the optimizations are sharding/schedule changes only)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import hieavg
from repro.launch import init_fl_histories, make_hfl_train_step
from repro.models import (forward_train, init_from_specs, loss_fn,
                          param_specs)
from repro.models import moe as moe_mod
from repro.models import transformer as tf_mod


def test_moe_block_size_invariance():
    """Block-einsum dispatch gives identical results for any block split
    when capacity is drop-free (same tokens reach the same experts)."""
    cfg = get_smoke("grok-1-314b")       # cf=16 -> drop-free
    params = init_from_specs(param_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
    old = moe_mod.MOE_BLOCK
    try:
        moe_mod.MOE_BLOCK = 8
        a, _ = forward_train(params, toks, cfg)
        moe_mod.MOE_BLOCK = 16
        b, _ = forward_train(params, toks, cfg)
        moe_mod.MOE_BLOCK = 999       # not divisible -> single block
        c, _ = forward_train(params, toks, cfg)
    finally:
        moe_mod.MOE_BLOCK = old
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


def test_chunked_loss_matches_unchunked():
    cfg = get_smoke("h2o-danube-1.8b")
    params = init_from_specs(param_specs(cfg), jax.random.key(0))
    s = tf_mod.LOSS_CHUNK * 2
    toks = jax.random.randint(jax.random.key(1), (1, s), 0, cfg.vocab)
    chunked = loss_fn(params, toks, toks, cfg)
    old = tf_mod.LOSS_CHUNK
    try:
        tf_mod.LOSS_CHUNK = s + 1     # force the unchunked path
        direct = loss_fn(params, toks, toks, cfg)
    finally:
        tf_mod.LOSS_CHUNK = old
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-5)


def test_microbatch_grad_accumulation_matches():
    """n_micro > 1 must give the same SGD step as n_micro = 1."""
    cfg = get_smoke("mamba2-130m")
    e, c, b, s = 1, 2, 4, 16
    base = init_from_specs(param_specs(cfg), jax.random.key(0))
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (e, c) + x.shape),
                          base)
    dev_hist, glob_hist = init_fl_histories(params)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (e, c, b, s),
                                          0, cfg.vocab),
             "labels": jax.random.randint(jax.random.key(2), (e, c, b, s),
                                          0, cfg.vocab)}
    masks = (jnp.ones((e, c), bool), jnp.ones((e,), bool))
    outs = []
    for nm in (1, 2):
        step = jax.jit(make_hfl_train_step(cfg, n_micro=nm))
        p2, _, _, loss = step(params, dev_hist, glob_hist, batch, *masks,
                              jnp.float32(1e-2))
        outs.append((p2, float(loss)))
    assert abs(outs[0][1] - outs[1][1]) < 1e-5
    for a, b_ in zip(jax.tree.leaves(outs[0][0]),
                     jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5)


def test_fp8_history_roundtrip():
    """fp8 histories keep HieAvg functional (estimation math stays f32)."""
    n = 4
    w = {"p": jax.random.normal(jax.random.key(0), (n, 64)) * 0.1}
    hist = hieavg.init_history(w, dtype=jnp.float8_e4m3fn)
    assert hist.prev_w["p"].dtype == jnp.float8_e4m3fn
    mask = jnp.array([True, False, True, True])
    agg, hist2 = hieavg.edge_aggregate(w, mask, hist, normalize=True)
    assert hist2.prev_w["p"].dtype == jnp.float8_e4m3fn
    assert not bool(jnp.isnan(agg["p"]).any())
    # fp8-quantized estimate stays within quantization error of bf16 path
    hist_b = hieavg.init_history(w)
    agg_b, _ = hieavg.edge_aggregate(w, mask, hist_b, normalize=True)
    np.testing.assert_allclose(np.asarray(agg["p"]), np.asarray(agg_b["p"]),
                               atol=0.02)


def test_hfl_step_with_straggler_estimation_end_to_end():
    """After a miss, the straggler's slot uses its history estimate — the
    global model must differ from the all-present one but stay finite."""
    cfg = get_smoke("deepseek-7b")
    e, c, b, s = 1, 2, 2, 16
    base = init_from_specs(param_specs(cfg), jax.random.key(0))
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (e, c) + x.shape),
                          base)
    dev_hist, glob_hist = init_fl_histories(params)
    step = jax.jit(make_hfl_train_step(cfg, normalize=True))
    batch = {"tokens": jnp.zeros((e, c, b, s), jnp.int32),
             "labels": jnp.zeros((e, c, b, s), jnp.int32)}
    st = (params, dev_hist, glob_hist)
    for t, mask in enumerate(([[True, True]], [[True, False]],
                              [[True, False]], [[True, True]])):
        p, dh, gh, loss = step(*st, batch, jnp.asarray(mask),
                               jnp.ones((e,), bool), jnp.float32(1e-3))
        st = (p, dh, gh)
        assert np.isfinite(float(loss)), t
    assert float(st[1].miss_count[0, 1]) == 0.0   # returned straggler
