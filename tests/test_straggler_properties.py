"""Property-style invariants of the straggler schedules (Sec. 2.4, 6.1.2)
and of the dense stacking used by the batched engine."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import straggler


@settings(max_examples=20, deadline=None)
@given(rounds=st.integers(5, 40), n=st.integers(2, 12),
       k=st.integers(1, 4), seed=st.integers(0, 99))
def test_temporary_miss_always_followed_by_submission(rounds, n, k, seed):
    m = straggler.temporary(rounds, n, min(k, n), seed=seed)
    miss = ~m
    # "continue to submit in the next round after the missing round"
    assert not (miss[:-1] & miss[1:]).any()


@settings(max_examples=20, deadline=None)
@given(rounds=st.integers(5, 40), n=st.integers(2, 12),
       k=st.integers(1, 4), seed=st.integers(0, 99),
       cold=st.integers(1, 3))
def test_temporary_cold_boot_rounds_never_missed(rounds, n, k, seed, cold):
    m = straggler.temporary(rounds, n, min(k, n), seed=seed,
                            cold_boot_rounds=cold)
    assert m[:cold].all()


@settings(max_examples=20, deadline=None)
@given(rounds=st.integers(6, 40), n=st.integers(2, 12),
       k=st.integers(1, 4), seed=st.integers(0, 99),
       stop=st.integers(1, 5))
def test_permanent_never_returns_after_stop_round(rounds, n, k, seed, stop):
    k = min(k, n)
    m = straggler.permanent(rounds, n, k, stop_round=stop, seed=seed)
    assert m[:stop].all(), "no one straggles before stop_round"
    cols = ~m[stop:]
    assert cols.all(axis=0).sum() == k, "exactly k permanent stragglers"
    # a permanent straggler never submits again: each column is all-miss
    # or all-submit after stop_round
    per_col = cols.any(axis=0) == cols.all(axis=0)
    assert per_col.all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_stack_ragged_layout(seed):
    rng = np.random.default_rng(seed)
    js = [int(rng.integers(1, 6)) for _ in range(4)]
    scheds = [straggler.temporary(12, j, max(j // 2, 1), seed=seed + i)
              for i, j in enumerate(js)]
    dense, valid = straggler.stack_ragged(scheds)
    assert dense.shape == (12, 4, max(js)) and valid.shape == (4, max(js))
    for e, j in enumerate(js):
        assert valid[e, :j].all() and not valid[e, j:].any()
        np.testing.assert_array_equal(dense[:, e, :j], scheds[e])
        assert not dense[:, e, j:].any(), "padded slots read as stragglers"


def test_stack_ragged_rejects_mismatched_rounds():
    a = straggler.no_stragglers(5, 2)
    b = straggler.no_stragglers(6, 2)
    try:
        straggler.stack_ragged([a, b])
    except ValueError:
        return
    raise AssertionError("expected ValueError for mismatched round counts")
