"""Optional-``hypothesis`` shim for the property-style tests.

When ``hypothesis`` is installed the real ``given``/``settings``/``st`` are
re-exported unchanged.  When it is absent (the CI image does not bake it in)
a deterministic fallback runs each property over a fixed, seeded list of
examples instead: every strategy is a draw function over a ``numpy``
Generator seeded from the test's qualified name, so failures reproduce
exactly across runs and machines.

Only the strategy surface the test-suite actually uses is implemented
(``integers``, ``sampled_from``, ``booleans``, ``floats``).  Tests import
from this module instead of ``hypothesis`` directly:

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect as _inspect
    import zlib

    import numpy as _np

    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        """A strategy is just a draw function rng -> example."""

        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

    st = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        """Accepts (and ignores) hypothesis kwargs like ``deadline``."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_compat_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                # stable per-test seed: failures replay identically
                rng = _np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    example = {k: s.draw(rng)
                               for k, s in sorted(strategies.items())}
                    fn(**example)

            # pytest introspects signatures to resolve fixtures; the strategy
            # args are filled here, so expose a parameterless signature
            del wrapper.__wrapped__
            wrapper.__signature__ = _inspect.Signature()
            return wrapper

        return deco
