"""The SeedSequence stream registry (``core.rng``) and the RNG bugfixes.

Two bugs motivated the registry:

* ``run_legacy`` consumed a generator stored on ``self`` — a second call
  continued the stream mid-way, so back-to-back runs of the SAME simulator
  disagreed.  ``run_legacy`` now opens a fresh ``"batches"`` stream per
  call (run-repeatability is bitwise).

* schedule seeds were derived ad hoc (``seed + 17 * e``, ``seed + 991``)
  so deployments at nearby base seeds shared schedules: ``sim(seed=0)``'s
  edge-1 device masks equalled ``sim(seed=17)``'s edge-0 masks.  Streams
  are now spawned via ``SeedSequence.spawn`` — collision-free by
  construction.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.bhfl_cnn import REDUCED
from repro.core import rng as rng_streams
from repro.core.rng import STREAMS, stream_rng, stream_seed, stream_seq
from repro.fl import BHFLSimulator

TINY = dataclasses.replace(REDUCED, t_global_rounds=4, n_edges=3,
                           j_per_edge=3, image_hw=8)
KW = dict(n_train=300, n_test=100, steps_per_epoch=2)


def test_streams_are_distinct():
    seeds = {stream_seed(0, name) for name in STREAMS}
    assert len(seeds) == len(STREAMS)


def test_indexed_substreams_are_distinct():
    seeds = {stream_seed(0, "dev_masks", e) for e in range(32)}
    seeds.add(stream_seed(0, "dev_masks"))
    assert len(seeds) == 33


def test_stream_is_deterministic():
    a = stream_rng(7, "latency").random(8)
    b = stream_rng(7, "latency").random(8)
    np.testing.assert_array_equal(a, b)
    assert stream_seed(7, "latency") == stream_seed(7, "latency")


def test_unknown_stream_raises():
    with pytest.raises(KeyError, match="unknown RNG stream"):
        stream_seq(0, "not-a-stream")
    with pytest.raises(ValueError, match="index must be >= 0"):
        stream_seq(0, "dev_masks", -1)


def test_nearby_base_seeds_do_not_collide():
    """The old ``seed + 17 * e`` derivation made sim(seed=0)'s edge-1
    masks equal sim(seed=17)'s edge-0 masks."""
    a = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", seed=0,
                      **KW)
    b = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", seed=17,
                      **KW)
    assert not np.array_equal(a.dev_masks[1], b.dev_masks[0])


def test_legacy_run_is_repeatable():
    """Back-to-back ``run_legacy`` calls on the SAME simulator are bitwise
    identical (the shared mutable ``self.rng`` bug)."""
    sim = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    r1 = sim.run_legacy()
    r2 = sim.run_legacy()
    np.testing.assert_array_equal(r1.accuracy, r2.accuracy)
    np.testing.assert_array_equal(r1.loss, r2.loss)


def test_legacy_matches_fresh_instance():
    """A used simulator's next run equals a fresh instance's first run —
    no hidden RNG state survives a run."""
    sim = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    sim.run_legacy()
    r_used = sim.run_legacy()
    r_fresh = BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                            **KW).run_legacy()
    np.testing.assert_array_equal(r_used.accuracy, r_fresh.accuracy)


def test_engine_and_legacy_share_batch_stream():
    """Both paths open the same ``"batches"`` stream, so engine/legacy
    parity survives the registry switch (the tolerance-level agreement is
    pinned by test_engine_parity; here just the stream identity)."""
    a = stream_rng(3, "batches").integers(0, 1000, 16)
    b = rng_streams.stream_rng(3, "batches").integers(0, 1000, 16)
    np.testing.assert_array_equal(a, b)
