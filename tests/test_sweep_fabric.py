"""The sweep fabric: shape-bucketed planner + mesh placement.

Every point of a padded grid — including grids over topology (N edges,
J devices per edge) and round counts (K, T), which change engine array
shapes per point — must reproduce a standalone ``BHFLSimulator.run`` of
the same setting, and padded extents must never contribute to any
aggregate.  Bucketing (grouping points into a few shape buckets instead
of padding everything to the single grid max) and the seed-deduped data
plane (one ``[n_seeds]`` dataset stack gathered by ``seed_idx`` inside
the engine) must both be invisible to numerics: the bucketed/deduped
grid is pinned per point against the single-bucket reference AND against
standalone runs that materialize their own data.  The multi-device
``shard_map`` path is pinned against ``vmap`` in
``test_multidevice_sweep.py`` (forced-host-device subprocess).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.bhfl_cnn import REDUCED
from repro.core import straggler
from repro.fl import (BHFLSimulator, build_inputs, plan_sweep, run_plan,
                      run_sweep)
from repro.fl.engine import run_engine

TINY = dataclasses.replace(REDUCED, t_global_rounds=3, n_edges=3,
                           j_per_edge=3, image_hw=8)
KW = dict(n_train=300, n_test=100, steps_per_epoch=2)


def _standalone(ov, seed=0, setting=TINY, kw=KW, **sim_kw):
    s = dataclasses.replace(setting, **ov)
    return BHFLSimulator(s, "hieavg", "temporary", "temporary", seed=seed,
                         **kw, **sim_kw).run()


def _check_point(sw, p, r):
    tv = int(sw.t_valid[p])
    np.testing.assert_allclose(sw.accuracy[p, :tv], r.accuracy, atol=1e-6)
    np.testing.assert_allclose(sw.loss[p, :tv], r.loss, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(sw.grad_norm[p, :tv], r.grad_norm, rtol=1e-4,
                               atol=1e-6)
    # latency fabric: per-point simulated clock parity rides every grid
    np.testing.assert_allclose(sw.sim_clock[p, :tv], r.sim_clock, rtol=1e-5)


# ----------------------------------------------------------- grid parity
def test_topology_grid_matches_standalone_runs():
    """N x J x K grid — shape-changing, ONE compiled call — per-point
    parity with individual engine runs (the acceptance criterion)."""
    overrides = [{"n_edges": n, "j_per_edge": j, "k_edge_rounds": k}
                 for n in (2, 3) for j in (2, 3) for k in (1, 2)]
    sw = run_sweep(TINY, overrides=overrides, **KW)
    assert sw.accuracy.shape == (8, TINY.t_global_rounds)
    for p, (ov, seed) in enumerate(sw.points):
        _check_point(sw, p, _standalone(ov, seed))


def test_ragged_round_counts():
    """t_global_rounds may vary per point; trailing rounds repeat the
    final valid accuracy and zero the loss/delta."""
    sw = run_sweep(TINY, overrides=[{"t_global_rounds": 2},
                                    {"t_global_rounds": 4}], **KW)
    assert sw.accuracy.shape == (2, 4)
    np.testing.assert_array_equal(sw.t_valid, [2, 4])
    for p, (ov, seed) in enumerate(sw.points):
        _check_point(sw, p, _standalone(ov, seed))
    # padded tail: accuracy/clock frozen at the final valid value, metrics
    # zeroed
    np.testing.assert_array_equal(sw.accuracy[0, 2:],
                                  np.repeat(sw.accuracy[0, 1], 2))
    np.testing.assert_array_equal(sw.loss[0, 2:], 0.0)
    np.testing.assert_array_equal(sw.grad_norm[0, 2:], 0.0)
    np.testing.assert_array_equal(sw.sim_clock[0, 2:],
                                  np.repeat(sw.sim_clock[0, 1], 2))
    acc, loss, gn = sw.trajectory(0)
    assert acc.shape == loss.shape == gn.shape == (2,)
    clock, acc_t = sw.latency_trajectory(0)
    assert clock.shape == acc_t.shape == (2,)


def test_varying_steps_per_epoch():
    """steps_per_epoch=None makes the step count depend on the device
    count (paper Sec. 6.1.5) — the planner pads the step axis too."""
    kw = dict(KW, steps_per_epoch=None)
    overrides = [{"j_per_edge": 2}, {"j_per_edge": 3}]
    sw = run_sweep(TINY, overrides=overrides, **kw)
    for p, (ov, seed) in enumerate(sw.points):
        _check_point(sw, p, _standalone(ov, seed, kw=kw))


def test_ragged_j_per_edge_list_override():
    """Fig. 4b inconsistent-J deployments ride through the planner."""
    sw = run_sweep(TINY, overrides=[{"j_per_edge": [1, 2, 3]}], **KW)
    r = BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                      j_per_edge=[1, 2, 3], **KW).run()
    _check_point(sw, 0, r)


@pytest.mark.parametrize("agg", ["t_fedavg", "d_fedavg"])
def test_topology_grid_other_aggregators(agg):
    ovs = [{"n_edges": 2}, {"k_edge_rounds": 1}]
    sw = run_sweep(TINY, overrides=ovs, aggregator=agg, **KW)
    for p, (ov, seed) in enumerate(sw.points):
        s = dataclasses.replace(TINY, **ov)
        r = BHFLSimulator(s, agg, "temporary", "temporary", seed=seed,
                          **KW).run()
        _check_point(sw, p, r)


# ------------------------------------------------------ padding invariants
def test_padding_is_a_numeric_noop():
    """A single deployment run through grid-max padding must match its
    unpadded self — padded slots never contribute to any aggregate."""
    sim_a = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    sim_b = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    inp = build_inputs(sim_a)
    pad = build_inputs(sim_b, t_max=5, k_max=4, n_max=5, j_max=6,
                       steps_max=4)
    a = run_engine(inp)
    b = run_engine(pad)
    T = TINY.t_global_rounds
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(y)[:T], np.asarray(x),
                                   rtol=1e-5, atol=1e-6)


def test_padded_inputs_are_inert():
    """Structural invariants: padded extents carry zero weight/lr/masks."""
    sim = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    pad = build_inputs(sim, t_max=5, k_max=4, n_max=5, j_max=6, steps_max=4)
    N, K, T, S = TINY.n_edges, TINY.k_edge_rounds, TINY.t_global_rounds, 2
    assert (int(pad.n_valid), int(pad.k_valid), int(pad.t_valid),
            int(pad.s_valid)) == (N, K, T, S)
    np.testing.assert_array_equal(np.asarray(pad.j_arr[N:]), 0.0)
    assert not np.asarray(pad.valid)[N:].any()
    assert not np.asarray(pad.valid)[:, 3:].any()      # j_per_edge=3
    assert not np.asarray(pad.dev_masks)[T:].any()
    assert not np.asarray(pad.dev_masks)[:, K:].any()
    assert not np.asarray(pad.edge_masks)[:, N:].any()
    np.testing.assert_array_equal(np.asarray(pad.lr)[T:], 0.0)
    np.testing.assert_array_equal(np.asarray(pad.lr)[:, K:], 0.0)
    assert not np.asarray(pad.has_data)[N:].any()
    assert not np.asarray(pad.batch_idx)[:, :, :, :, S:].any()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), n_max=st.integers(4, 7))
def test_stack_ragged_n_max_pads_inert_edges(seed, n_max):
    rng = np.random.default_rng(seed)
    js = [int(rng.integers(1, 5)) for _ in range(3)]
    scheds = [straggler.temporary(8, j, max(j // 2, 1), seed=seed + i)
              for i, j in enumerate(js)]
    dense, valid = straggler.stack_ragged(scheds, n_max=n_max)
    assert dense.shape == (8, n_max, max(js))
    assert not dense[:, 3:].any() and not valid[3:].any()
    for e, j in enumerate(js):
        np.testing.assert_array_equal(dense[:, e, :j], scheds[e])


def test_stack_ragged_rejects_too_small_n_max():
    scheds = [straggler.no_stragglers(4, 2)] * 3
    with pytest.raises(ValueError, match="n_max"):
        straggler.stack_ragged(scheds, n_max=2)


def test_build_inputs_rejects_undersized_pad_targets():
    sim = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    with pytest.raises(ValueError, match="pad targets"):
        build_inputs(sim, j_max=2)       # j_per_edge=3
    sim = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    with pytest.raises(ValueError, match="pad targets"):
        build_inputs(sim, t_max=1)       # t_global_rounds=3


# ------------------------------------------------------------ error paths
def test_unsupported_field_raises_naming_it():
    with pytest.raises(ValueError, match="image_hw"):
        run_sweep(TINY, overrides=[{"image_hw": 10}], **KW)
    with pytest.raises(ValueError, match="batch_size"):
        run_sweep(TINY, overrides=[{"batch_size": 8}], **KW)


def test_unknown_field_raises_naming_it():
    with pytest.raises(ValueError, match="not_a_field"):
        run_sweep(TINY, overrides=[{"not_a_field": 1}], **KW)


def test_mismatched_ragged_j_per_edge_raises():
    """A ragged device list must name every edge exactly once — silently
    inflating D (steps, latency) would corrupt results, not crash."""
    with pytest.raises(ValueError, match="n_edges"):
        run_sweep(TINY, overrides=[{"n_edges": 2,
                                    "j_per_edge": [3, 4, 5]}], **KW)


def test_forced_shard_raises_clearly_on_one_device():
    with pytest.raises(ValueError, match="placement='shard'"):
        run_sweep(TINY, overrides=[{}, {"straggler_frac": 0.4}],
                  placement="shard", **KW)


# ---------------------------------------------------------- history dtype
def test_history_dtype_f8_runs_and_stays_close():
    """EXPERIMENTS.md X1: f8 history storage is a memory/accuracy knob,
    not a correctness switch — trajectories stay finite and close to f32
    at tiny scale."""
    f32 = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW).run()
    f8 = BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                       history_dtype=jnp.float8_e4m3fn, **KW).run()
    assert np.all(np.isfinite(f8.accuracy)) and np.all(np.isfinite(f8.loss))
    np.testing.assert_allclose(f8.loss, f32.loss, rtol=0.2, atol=0.05)


def test_seed_override_is_honored():
    """A {"seed": ...} override pins that point's seed — it is neither
    silently ignored (the simulator's explicit seed argument would
    otherwise win) nor crossed with the ``seeds`` tuple (which would emit
    duplicate identical points)."""
    sw = run_sweep(TINY, seeds=(0, 1),
                   overrides=[{"seed": 2}, {"straggler_frac": 0.2}], **KW)
    assert [s for _, s in sw.points] == [2, 0, 1]   # pinned, then crossed
    assert not np.array_equal(sw.accuracy[0], sw.accuracy[1])
    _check_point(sw, 0, _standalone({}, 2))


def test_history_dtype_threads_through_sweep():
    sw = run_sweep(TINY, overrides=[{"n_edges": 2}, {}],
                   history_dtype=jnp.float8_e4m3fn, **KW)
    assert np.all(np.isfinite(sw.accuracy))


# ----------------------------------------------------------------- planner
def test_plan_exposes_grid_maxima_and_stacked_inputs():
    plan = plan_sweep(TINY, overrides=[{"n_edges": 2, "k_edge_rounds": 2},
                                       {"n_edges": 4, "j_per_edge": 2}],
                      max_buckets=1, **KW)
    assert plan.grid_max["n"] == 4 and plan.grid_max["j"] == 3
    assert plan.grid_max["k"] == TINY.k_edge_rounds
    # max_buckets=1: the PR 2 single global-max stack; plan.inputs is the
    # single-bucket convenience accessor
    assert len(plan.buckets) == 1
    assert plan.inputs.dev_masks.shape == (
        2, plan.grid_max["t"], plan.grid_max["k"], plan.grid_max["n"],
        plan.grid_max["j"])


def test_plan_dedups_dataset_by_distinct_seed():
    """The data plane is seed-major: one ``[n_seeds]`` stack of the
    train/test/init arrays (they are a pure function of seed +
    grid-constant geometry) shared by every bucket, with per-point
    ``seed_idx`` gather indices — NEVER one dataset copy per point."""
    one = plan_sweep(TINY, overrides=[{"straggler_frac": 0.2},
                                      {"straggler_frac": 0.4}], **KW)
    assert one.n_seeds == 1
    assert one.inputs.train_x.shape == (1, KW["n_train"],
                                        TINY.image_hw, TINY.image_hw, 1)
    assert one.inputs.batch_idx.shape[0] == 2        # point plane stacked
    # single-seed plan: seed_idx stays a shared scalar (unmapped under
    # vmap, so the engine's test/init gathers stay unbatched)
    assert np.asarray(one.inputs.seed_idx).shape == ()
    assert int(one.inputs.seed_idx) == 0

    multi = plan_sweep(TINY, seeds=(0, 1), overrides=[{}, {"gamma0": 0.5}],
                       **KW)
    assert multi.n_seeds == 2
    # 4 points, but only 2 dataset rows — memory scales with seeds
    assert len(multi.points) == 4
    assert multi.inputs.train_x.shape[0] == 2
    np.testing.assert_array_equal(np.asarray(multi.inputs.seed_idx),
                                  [0, 1, 0, 1])


def test_bucketing_bounds_programs_and_cuts_padding():
    """The padding-waste heuristic: at most ``max_buckets`` buckets, every
    point in exactly one bucket, and strictly less padded compute than the
    single global-max bucket on a mixed-shape grid."""
    ovs = [{"n_edges": 2}, {"n_edges": 4}, {"j_per_edge": 2},
           {"k_edge_rounds": 1}, {"t_global_rounds": 2}, {}]
    auto = plan_sweep(TINY, overrides=ovs, max_buckets=3, bucket_waste=1.0,
                      **KW)
    single = plan_sweep(TINY, overrides=ovs, max_buckets=1, **KW)
    assert len(single.buckets) == 1
    assert 1 < len(auto.buckets) <= 3
    assert sorted(i for b in auto.buckets for i in b.point_ids) \
        == list(range(len(ovs)))
    sa, ss = auto.padding_stats(), single.padding_stats()
    assert sa["ideal_volume"] == ss["ideal_volume"]
    assert sa["padded_volume"] < ss["padded_volume"]
    assert 0.0 <= sa["padded_flop_frac"] < sa["single_bucket_flop_frac"]
    # per-bucket inputs are padded to the bucket max, not the global max
    assert any(b.inputs.dev_masks.shape[1:] != (
        single.grid_max["t"], single.grid_max["k"], single.grid_max["n"],
        single.grid_max["j"]) for b in auto.buckets)
    assert "bucket" in auto.describe()
    with pytest.raises(ValueError, match="buckets"):
        auto.inputs          # multi-bucket plan: no single stacked inputs
    with pytest.raises(ValueError, match="max_buckets"):
        plan_sweep(TINY, overrides=ovs, max_buckets=0, **KW)


def test_identical_shapes_always_share_a_bucket():
    """Shape-preserving grids (fig7-style data-only sweeps) stay ONE
    compiled call no matter the bucketing knobs."""
    plan = plan_sweep(TINY, overrides=[{"straggler_frac": f}
                                       for f in (0.0, 0.2, 0.4)],
                      max_buckets=4, bucket_waste=1.0, **KW)
    assert len(plan.buckets) == 1
    assert plan.padding_stats()["padded_flop_frac"] == 0.0


# ------------------------------------------------- bucketed execution parity
def test_bucketed_grid_matches_single_bucket_and_standalone():
    """The acceptance criterion: a fig3-style mixed J/N/K grid run through
    ≤3 bucketed programs matches the single-bucket reference per point
    (trajectories and sim_clock) AND standalone runs."""
    ovs = [{"n_edges": 2}, {"n_edges": 4}, {"j_per_edge": 2},
           {"k_edge_rounds": 1}, {"t_global_rounds": 2}, {}]
    bucketed = run_sweep(TINY, overrides=ovs, max_buckets=3,
                         bucket_waste=1.0, **KW)
    single = run_sweep(TINY, overrides=ovs, max_buckets=1, **KW)
    assert bucketed.accuracy.shape == single.accuracy.shape
    np.testing.assert_allclose(bucketed.accuracy, single.accuracy,
                               atol=1e-6)
    np.testing.assert_allclose(bucketed.loss, single.loss, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(bucketed.grad_norm, single.grad_norm,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(bucketed.sim_clock, single.sim_clock,
                               rtol=1e-5)
    for p, (ov, seed) in enumerate(bucketed.points):
        _check_point(bucketed, p, _standalone(ov, seed))


def test_seed_dedup_gather_matches_per_point_materialized_data():
    """A ≥3-seed grid pulls every point's dataset through the in-engine
    ``seed_idx`` gather of the shared ``[n_seeds]`` plane; standalone runs
    materialize their own data — the two must agree exactly."""
    sw = run_sweep(TINY, seeds=(0, 1, 2),
                   overrides=[{}, {"straggler_frac": 0.4}], **KW)
    assert len(sw.points) == 6
    for p, (ov, seed) in enumerate(sw.points):
        _check_point(sw, p, _standalone(ov, seed))
    # distinct seeds genuinely produce distinct data/trajectories
    assert not np.array_equal(sw.accuracy[0], sw.accuracy[1])


def test_seed_dedup_composes_with_bucketing():
    """Multi-seed x mixed-shape: buckets may split seed groups arbitrarily;
    every bucket still gathers from the one shared data plane."""
    plan = plan_sweep(TINY, seeds=(0, 1),
                      overrides=[{}, {"n_edges": 2, "k_edge_rounds": 1}],
                      max_buckets=2, bucket_waste=1.0, **KW)
    assert plan.n_seeds == 2 and len(plan.buckets) == 2
    for b in plan.buckets:
        assert b.inputs.train_x.shape[0] == 2        # full plane everywhere
        # same device buffers in every bucket, not copies
        assert b.inputs.train_x is plan.buckets[0].inputs.train_x
    sw = run_plan(plan)
    for p, (ov, seed) in enumerate(sw.points):
        _check_point(sw, p, _standalone(ov, seed))
