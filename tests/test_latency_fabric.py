"""The latency fabric: statistical consensus model, dense-K optimizer,
engine clock accounting, and the sweep-level K* selector.

Three anchors:
  * the closed-form Raft expectations are pinned by Monte-Carlo replay of
    the discrete-event ``RaftChain`` (the reference implementation) over a
    link_latency × N grid,
  * the traced dense-K latency model is pinned to the scalar float64
    reference on a K <= 64 enumeration,
  * every sweep point's simulated-clock trajectory is pinned to a
    standalone engine run (the per-point parity the fabric guarantees).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bhfl_cnn import REDUCED
from repro.core import (BoundParams, LatencyParams, RaftChain, RaftParams,
                        edge_window, edge_window_k,
                        expected_consensus_latency,
                        expected_election_latency, omega_bound,
                        omega_bound_k, optimize_k, optimize_k_masked,
                        total_latency, total_latency_k)
from repro.fl import BHFLSimulator, run_sweep
from repro.fl.sweep import SweepResult

TINY = dataclasses.replace(REDUCED, t_global_rounds=3, n_edges=3,
                           j_per_edge=3, image_hw=8)
KW = dict(n_train=300, n_test=100, steps_per_epoch=2)


# ------------------------------------------- statistical consensus model
@pytest.mark.parametrize("link,n", [(0.05, 3), (0.05, 5), (0.5, 5),
                                    (0.2, 9)])
def test_expected_election_latency_matches_monte_carlo(link, n):
    """Closed-form E[election] within 5% of 400-seed RaftChain replay."""
    p = RaftParams(link_latency=link)
    ts = []
    for seed in range(400):
        chain = RaftChain(n, p, seed=seed)
        _, t = chain.elect_leader()
        ts.append(t)
    mc = float(np.mean(ts))
    cf = expected_election_latency(p, n)
    assert abs(mc - cf) / mc < 0.05


def test_expected_consensus_latency_matches_monte_carlo():
    """Full per-round consensus (election + commit) within 5% of MC."""
    p = RaftParams()
    ts = []
    for seed in range(400):
        chain = RaftChain(5, p, seed=seed)
        _, t_e = chain.elect_leader()
        _, t_c = chain.commit_block("e", "g")
        ts.append(t_e + t_c)
    mc = float(np.mean(ts))
    cf = expected_consensus_latency(p, 5)
    assert abs(mc - cf) / mc < 0.05


def test_expected_election_degraded_quorum():
    """Fewer alive voters -> longer expected timeout (min of fewer
    uniforms); below majority -> inf (elect_leader raises there)."""
    p = RaftParams()
    full = expected_election_latency(p, 5)
    degraded = expected_election_latency(p, 5, n_alive=3)
    assert degraded > full
    assert expected_election_latency(p, 5, n_alive=2) == float("inf")


def test_replication_only_matches_chain_consensus_latency():
    p = RaftParams(link_latency=0.2)
    chain = RaftChain(5, p)
    assert expected_consensus_latency(p, 5, include_election=False) \
        == pytest.approx(chain.consensus_latency())


def test_elect_leader_raises_without_majority():
    """Satellite bugfix: the win condition can never hold below majority —
    the old code spun forever instead of raising."""
    chain = RaftChain(5, seed=0)
    chain.elect_leader()
    for i in range(3):
        chain.fail_node(i)
    with pytest.raises(RuntimeError, match="no majority alive"):
        chain.elect_leader()


# ------------------------------------------------- dense-K traced model
@pytest.mark.parametrize("lp", [LatencyParams(),
                                LatencyParams(T=10, N=3, J=7,
                                              lm_device=0.1, lp_device=3.0,
                                              lm_edge=0.4)])
def test_vectorized_latency_matches_scalar_reference(lp):
    """total_latency_k / edge_window_k == the float64 scalar model on a
    K <= 64 enumeration."""
    lat = np.asarray(total_latency_k(lp, 64))
    win = np.asarray(edge_window_k(lp, 64))
    for i, k in enumerate(range(1, 65)):
        np.testing.assert_allclose(lat[i], total_latency(k, lp), rtol=1e-5)
        np.testing.assert_allclose(win[i], edge_window(k, lp), rtol=1e-5)


def test_omega_bound_k_matches_scalar():
    bp = BoundParams()
    om = np.asarray(omega_bound_k(bp, 64))
    ref = np.array([omega_bound(k, bp) for k in range(1, 65)])
    np.testing.assert_allclose(om, ref, rtol=1e-4)


@pytest.mark.parametrize("omega_bar,lbc", [(25.0, 0.5), (25.0, 8.0),
                                           (9.5, 0.5), (1e-9, 0.01)])
def test_optimize_k_masked_matches_host_optimizer(omega_bar, lbc):
    """The traced masked-argmin K* == the host enumeration, including the
    all-infeasible case (-1 vs None)."""
    lp, bp = LatencyParams(), BoundParams()
    k_star, k_lat, feas = optimize_k_masked(
        total_latency_k(lp, 64), omega_bound_k(bp, 64),
        edge_window_k(lp, 64), omega_bar, lbc)
    ref = optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=omega_bar,
                     consensus_latency=lbc)
    if ref is None:
        assert int(k_star) == -1 and not np.isfinite(float(k_lat))
    else:
        assert int(k_star) == ref.k_star
        np.testing.assert_allclose(float(k_lat), ref.latency, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(feas), ref.feasible)


def test_optimize_k_masked_is_vmappable():
    """A grid of K* solves batches into one vmapped call — the sweep-fabric
    use case the dense axis exists for."""
    bp = BoundParams()
    lms = jnp.asarray([0.1, 0.51, 2.0])

    def solve(lm):
        lp = dataclasses.replace(LatencyParams(), lm_device=lm)
        k, lat, _ = optimize_k_masked(
            total_latency_k(lp, 32), omega_bound_k(bp, 32),
            edge_window_k(lp, 32), 25.0, 3.0)
        return k, lat

    ks, lats = jax.vmap(solve)(lms)
    for i, lm in enumerate([0.1, 0.51, 2.0]):
        lp = dataclasses.replace(LatencyParams(), lm_device=lm)
        ref = optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=25.0,
                         consensus_latency=3.0, k_max=32)
        assert int(ks[i]) == ref.k_star
        np.testing.assert_allclose(float(lats[i]), ref.latency, rtol=1e-5)


# ---------------------------------------------------- input validation
def test_optimize_k_rejects_bad_k_max():
    lp, bp = LatencyParams(), BoundParams()
    for bad in (0, -3, 2.5):
        with pytest.raises(ValueError, match="k_max"):
            optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=25.0,
                       consensus_latency=0.5, k_max=bad)


def test_optimize_k_rejects_non_finite_inputs():
    lp, bp = LatencyParams(), BoundParams()
    for bad in (float("inf"), float("nan")):
        with pytest.raises(ValueError, match="omega_bar"):
            optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=bad,
                       consensus_latency=0.5)
        with pytest.raises(ValueError, match="consensus_latency"):
            optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=25.0,
                       consensus_latency=bad)
    with pytest.raises(ValueError, match="consensus_latency"):
        optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=25.0,
                   consensus_latency=-1.0)


# ------------------------------------------------- engine clock accounting
def test_engine_clock_is_positive_and_increasing():
    r = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW).run()
    assert r.sim_clock is not None and r.sim_clock.shape == (3,)
    assert r.sim_clock[0] > 0
    assert np.all(np.diff(r.sim_clock) > 0)


def test_sweep_latency_trajectories_match_standalone_runs():
    """Per-point clock parity across a latency × topology × K grid — the
    acceptance criterion: padding and batching never perturb a point's
    simulated clock."""
    overrides = [{"consensus_mult": 30.0}, {"lp_device": 4.0},
                 {"n_edges": 2, "k_edge_rounds": 1},
                 {"link_latency": 0.4, "k_edge_rounds": 1}]
    sw = run_sweep(TINY, overrides=overrides, **KW)
    for p, (ov, seed) in enumerate(sw.points):
        s = dataclasses.replace(TINY, **ov)
        r = BHFLSimulator(s, "hieavg", "temporary", "temporary", seed=seed,
                          **KW).run()
        clock, acc = sw.latency_trajectory(p)
        np.testing.assert_allclose(clock, r.sim_clock, rtol=1e-5)
        np.testing.assert_allclose(acc, r.accuracy, atol=1e-6)


def test_consensus_mult_and_stragglers_slow_the_clock():
    """Physics of the accounting: a consensus latency too large for the
    edge window stalls rounds (C2), and stragglers push rounds toward the
    submission deadline."""
    base = BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                         **KW).run()
    slow_cons = BHFLSimulator(
        dataclasses.replace(TINY, consensus_mult=100.0),
        "hieavg", "temporary", "temporary", **KW).run()
    assert slow_cons.sim_clock[-1] > base.sim_clock[-1]

    quiet = BHFLSimulator(dataclasses.replace(TINY, straggler_frac=0.0),
                          "hieavg", "none", "none", **KW).run()
    strag = BHFLSimulator(dataclasses.replace(TINY, straggler_frac=0.5),
                          "hieavg", "temporary", "temporary", **KW).run()
    assert strag.sim_clock[-1] > quiet.sim_clock[-1]


def test_clock_trajectory_reflects_deployment_scale():
    """Sanity of magnitudes: per-round simulated time sits between the
    expectation (2 lm + lp per edge round, K rounds) and the deadline."""
    r = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW).run()
    sim = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW)
    k = TINY.k_edge_rounds
    expect = k * (2 * TINY.lm_device + TINY.lp_device)
    deadline = k * sim.lat.deadline_mult * (2 * TINY.lm_device
                                            + TINY.lp_device)
    per_round = np.diff(np.concatenate([[0.0], r.sim_clock]))
    lo = expect * (1 - max(sim.lat.lm_jitter, sim.lat.lp_jitter))
    hi = deadline + 2 * TINY.lm_edge + 10.0   # + hop + consensus stall slack
    assert np.all(per_round > lo) and np.all(per_round < hi)


# ------------------------------------------------------- K* selector
def _fake_result(accs, clocks):
    accs = np.asarray(accs, np.float32)
    clocks = np.asarray(clocks, np.float32)
    P, T = accs.shape
    zeros = np.zeros_like(accs)
    return SweepResult(points=[({}, 0)] * P, accuracy=accs, loss=zeros,
                       grad_norm=zeros, sim_clock=clocks, sim_energy=zeros,
                       sim_latency=np.zeros(P), blocks=np.zeros(P),
                       t_valid=np.full(P, T))


def test_time_to_accuracy_first_hit():
    sw = _fake_result([[0.1, 0.5, 0.9]], [[10.0, 20.0, 30.0]])
    assert sw.time_to_accuracy(0, 0.5) == 20.0
    assert sw.time_to_accuracy(0, 0.95) == float("inf")


def test_k_star_empirical_picks_fastest_point():
    # point 1 converges in fewer rounds AND less simulated time
    sw = _fake_result([[0.2, 0.4, 0.6], [0.5, 0.7, 0.8], [0.1, 0.2, 0.3]],
                      [[5.0, 10.0, 15.0], [8.0, 16.0, 24.0],
                       [1.0, 2.0, 3.0]])
    best, times = sw.k_star_empirical(0.5)
    assert best == 1                    # hits 0.5 at t=8 vs point 0's t=15
    np.testing.assert_allclose(times, [15.0, 8.0, np.inf])


def test_k_star_empirical_all_infeasible():
    sw = _fake_result([[0.1, 0.2]], [[1.0, 2.0]])
    best, times = sw.k_star_empirical(0.99)
    assert best is None and not np.isfinite(times).any()
