import os

# Tests see the real single CPU device (the dry-run sets its own XLA_FLAGS
# in-process before importing jax; never set device-count flags here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
