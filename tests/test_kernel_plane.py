"""Kernel plane: backend dispatch, fused-kernel engine parity, donation.

Three pin groups (see docs/ARCHITECTURE.md §Kernel plane):

  * kernel oracles — ``hieavg_agg`` / ``sgd_update`` against their
    pure-jnp refs across tile-tail shapes (L not a multiple of TILE,
    L < TILE) and the mixed-dtype bf16 ``history_dtype`` layout,
  * engine parity — ``kernel_mode="interpret"`` (the fused kernels
    through the Pallas interpreter, the only kernel execution CPU has)
    must reproduce the pure-XLA engine on standalone runs AND across a
    padded multi-bucket sweep grid; the 4-device shard_map pin lives in
    ``test_multidevice_sweep.py``,
  * donation — the donated engine/sweep entries return the same numbers
    as the non-donated ones, never consume the shared data plane, and a
    donated plan is consumed exactly once.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.bhfl_cnn import REDUCED
from repro.core import baselines, hieavg
from repro.fl import BHFLSimulator, build_inputs, plan_sweep, run_plan, \
    run_sweep
from repro.fl.engine import (SHARED_DATA_FIELDS, run_engine,
                             run_engine_donated, split_inputs)
from repro.kernels import dispatch as kd
from repro.kernels.coef_agg import TILE as CTILE
from repro.kernels.coef_agg import coef_agg, coef_agg_pair
from repro.kernels.conv3x3 import conv3x3_bias_relu
from repro.kernels.eval_head import eval_head
from repro.kernels.ops import (fused_edge_aggregate_batched,
                               fused_mix_and_update)
from repro.kernels.ref import (coef_agg_pair_ref, coef_agg_ref,
                               conv3x3_bias_relu_ref, eval_head_ref,
                               sgd_update_ref)
from repro.kernels.sgd_update import TILE, sgd_update

TINY = dataclasses.replace(REDUCED, t_global_rounds=3, n_edges=3,
                           j_per_edge=3, image_hw=8)
KW = dict(n_train=300, n_test=100, steps_per_epoch=2)


def _sim(kernel_mode="auto", **kw):
    return BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                         kernel_mode=kernel_mode, **KW, **kw)


def _close(a, b, *, acc_atol=1e-6):
    np.testing.assert_allclose(b.accuracy, a.accuracy, atol=acc_atol)
    np.testing.assert_allclose(b.loss, a.loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b.grad_norm, a.grad_norm, rtol=1e-4,
                               atol=1e-6)


# ---------------------------------------------------------------- dispatch
def test_resolve_kernel_mode_cpu_auto_is_xla():
    """On CPU "auto" must pick the XLA reference — never the interpreter
    (the satellite bugfix: nothing ever 'flips interpret off', so the
    default has to be backend detection, and CPU has no Pallas backend)."""
    assert jax.default_backend() == "cpu"
    assert kd.resolve_kernel_mode("auto") == "xla"
    assert kd.default_interpret() is True
    for mode in ("pallas", "interpret", "xla"):
        assert kd.resolve_kernel_mode(mode) == mode


def test_unknown_kernel_mode_raises_naming_the_choices():
    with pytest.raises(ValueError, match="auto"):
        kd.resolve_kernel_mode("mosaic")
    with pytest.raises(ValueError, match="kernel_mode"):
        BHFLSimulator(TINY, kernel_mode="nope", **KW)
    with pytest.raises(ValueError, match="kernel_mode"):
        run_sweep(TINY, kernel_mode="nope", **KW)


# ----------------------------------------------------------- kernel oracles
# Every test in this group is marked ``kernel_oracle``: CI runs them as a
# dedicated interpret-mode oracle-parity job (`pytest -m kernel_oracle`).
@pytest.mark.kernel_oracle
@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 9),
       l=st.sampled_from([1, 7, 100, TILE - 1, TILE, TILE + 1, 3 * TILE]),
       seed=st.integers(0, 99))
def test_sgd_update_matches_ref_on_tile_tails(n, l, seed):
    ks = jax.random.split(jax.random.key(seed), 2)
    w = jax.random.normal(ks[0], (n, l))
    g = jax.random.normal(ks[1], (n, l))
    got = sgd_update(w, g, jnp.float32(0.37), interpret=True)
    ref = sgd_update_ref(w, g, 0.37)
    # 1-ulp slack: XLA may contract the multiply-subtract into an FMA in
    # one lowering and not the other
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.kernel_oracle
def test_sgd_update_zero_scale_is_exact_identity():
    """scale = lr x step-validity: a padded sweep step (0) must be an
    exact no-op, bitwise."""
    w = jax.random.normal(jax.random.key(0), (4, 333))
    g = jax.random.normal(jax.random.key(1), (4, 333)) * 1e3
    got = sgd_update(w, g, jnp.float32(0.0), interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w))


@pytest.mark.kernel_oracle
def test_sgd_update_bf16_storage():
    w = jax.random.normal(jax.random.key(0), (3, 100), jnp.bfloat16)
    g = jax.random.normal(jax.random.key(1), (3, 100), jnp.bfloat16)
    got = sgd_update(w, g, jnp.float32(0.1), interpret=True)
    assert got.dtype == jnp.bfloat16
    ref = sgd_update_ref(w, g, 0.1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=1e-6,
                               atol=1e-7)


@pytest.mark.kernel_oracle
@pytest.mark.parametrize("l", [1, 40, TILE + 3])
def test_hieavg_agg_mixed_history_dtype(l):
    """The engine's ``history_dtype`` layout: f32 submissions, bf16
    history leaves — each kernel output casts back to its own operand's
    dtype (the history stays bf16, the aggregate stays f32)."""
    from repro.kernels.hieavg_agg import hieavg_agg
    from repro.kernels.ref import hieavg_agg_ref

    n = 5
    ks = jax.random.split(jax.random.key(3), 5)
    w = jax.random.normal(ks[0], (n, l))
    prev = jax.random.normal(ks[1], (n, l), jnp.bfloat16)
    dmean = (jax.random.normal(ks[2], (n, l)) * 0.1).astype(jnp.bfloat16)
    mask = jax.random.bernoulli(ks[3], 0.6, (n,))
    cp = jax.random.uniform(ks[4], (n,))
    ce = (1.0 - cp) * 0.3
    nobs = jnp.arange(n, dtype=jnp.float32)
    ref = hieavg_agg_ref(w, prev, dmean, mask, cp, ce, nobs)
    got = hieavg_agg(w, prev, dmean, mask, cp, ce, nobs, interpret=True)
    assert got[0].dtype == jnp.float32
    assert got[1].dtype == got[2].dtype == jnp.bfloat16
    for r, g in zip(ref, got):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(r, np.float32), atol=6e-2)


@pytest.mark.kernel_oracle
def test_fused_batched_matches_core_batched_with_padding():
    """The engine's dense-layer entry: fused [N, J] aggregation ==
    ``hieavg.edge_aggregate_batched`` on a validity-masked layout with
    garbage in the padded slots, traced gamma/lam."""
    n_edges, j = 3, 4
    ks = jax.random.split(jax.random.key(0), 3)
    w = {"a": jax.random.normal(ks[0], (n_edges, j, 5, 3)),
         "b": jax.random.normal(ks[1], (n_edges, j, 17))}
    valid = jnp.asarray([[1, 1, 1, 0], [1, 1, 0, 0], [1, 1, 1, 1]], bool)
    mask = jax.random.bernoulli(ks[2], 0.6, (n_edges, j)) & valid
    hist = hieavg.init_history_batched(w)
    w1 = jax.tree.map(lambda x: x * 1.1 + 0.1, w)
    hist = hieavg.update_history_batched(hist, w1, valid)
    g0, lam = jnp.float32(0.9), jnp.float32(0.8)
    for normalize in (False, True):
        a_ref, h_ref = hieavg.edge_aggregate_batched(
            w1, mask, hist, valid, g0, lam, normalize)
        a_got, h_got = fused_edge_aggregate_batched(
            w1, mask, hist, valid, g0, lam, normalize, interpret=True)
        for k in w:
            np.testing.assert_allclose(np.asarray(a_got[k]),
                                       np.asarray(a_ref[k]), atol=1e-6)
            np.testing.assert_allclose(np.asarray(h_got.prev_w[k]),
                                       np.asarray(h_ref.prev_w[k]),
                                       atol=1e-6)
            np.testing.assert_allclose(np.asarray(h_got.delta_mean[k]),
                                       np.asarray(h_ref.delta_mean[k]),
                                       atol=1e-6)
        np.testing.assert_array_equal(np.asarray(h_got.n_obs),
                                      np.asarray(h_ref.n_obs))


@pytest.mark.kernel_oracle
def test_fused_global_matches_core_traced_weights():
    """Eq. (5) with J-weighted traced part weights — the engine's global
    layer call."""
    n = 3
    w = {"p": jax.random.normal(jax.random.key(9), (n, 7, 2))}
    hist = hieavg.init_history(w)
    hist = hieavg.update_history(hist, jax.tree.map(lambda x: x * 1.1, w),
                                 jnp.ones(n, bool))
    j_arr = jnp.asarray([3.0, 2.0, 4.0])
    pw = j_arr / jnp.sum(j_arr)
    mask = jnp.asarray([True, False, True])
    a_ref, _ = hieavg.aggregate(w, mask, hist, pw, jnp.float32(0.9),
                                jnp.float32(0.9), True)
    a_got, _ = fused_mix_and_update(w, mask, hist, pw, jnp.float32(0.9),
                                    jnp.float32(0.9), True, interpret=True)
    np.testing.assert_allclose(np.asarray(a_got["p"]),
                               np.asarray(a_ref["p"]), atol=1e-6)


# ------------------------------------------------- conv / eval / coef oracles
@pytest.mark.kernel_oracle
@pytest.mark.parametrize("b,hw,cin,cout", [
    (1, 5, 1, 3),     # M = 25 < TILE_M, single ragged tile
    (2, 12, 4, 8),    # M = 288: one full tile + tail
    (2, 16, 3, 7),    # M = 512: exact tile multiple, odd cout
])
def test_conv3x3_matches_ref_on_tile_tails(b, hw, cin, cout):
    """The fused conv epilogue across M-tile tails (B·H·W not a multiple
    of the 256-row tile) and non-multiple-of-anything channel counts."""
    ks = jax.random.split(jax.random.key(0), 3)
    x = jax.random.normal(ks[0], (b, hw, hw, cin))
    w = jax.random.normal(ks[1], (3, 3, cin, cout)) * 0.3
    bb = jax.random.normal(ks[2], (cout,)) * 0.3
    got = conv3x3_bias_relu(x, w, bb, interpret=True)
    ref = conv3x3_bias_relu_ref(x, w, bb)
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


@pytest.mark.kernel_oracle
def test_conv3x3_grads_match_ref():
    """The custom VJP: dx (via the XLA col2im autodiff of the im2col
    construction), dw and db (the Pallas backward matmuls) against the
    pure-jnp reference's autodiff."""
    ks = jax.random.split(jax.random.key(1), 4)
    x = jax.random.normal(ks[0], (2, 9, 9, 3))
    w = jax.random.normal(ks[1], (3, 3, 3, 5)) * 0.3
    b = jax.random.normal(ks[2], (5,)) * 0.3
    dy = jax.random.normal(ks[3], (2, 9, 9, 5))

    def loss(fn):
        return lambda x, w, b: jnp.sum(fn(x, w, b) * dy)

    gx, gw, gb = jax.grad(
        loss(lambda x, w, b: conv3x3_bias_relu(x, w, b, interpret=True)),
        argnums=(0, 1, 2))(x, w, b)
    rx, rw, rb = jax.grad(loss(conv3x3_bias_relu_ref),
                          argnums=(0, 1, 2))(x, w, b)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb), atol=1e-4)


@pytest.mark.kernel_oracle
def test_conv3x3_bf16_storage():
    """bf16 operands: f32 tile math, output cast back to bf16 — matching
    the reference's f32-accumulate-then-cast within bf16 rounding."""
    ks = jax.random.split(jax.random.key(2), 3)
    x = jax.random.normal(ks[0], (2, 8, 8, 4), jnp.bfloat16)
    w = (jax.random.normal(ks[1], (3, 3, 4, 6)) * 0.3).astype(jnp.bfloat16)
    b = (jax.random.normal(ks[2], (6,)) * 0.3).astype(jnp.bfloat16)
    got = conv3x3_bias_relu(x, w, b, interpret=True)
    ref = conv3x3_bias_relu_ref(x, w, b)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=1e-2)


@pytest.mark.kernel_oracle
@pytest.mark.parametrize("m", [1, 100, 256, 257, 400])
def test_eval_head_matches_ref_on_tile_tails(m):
    """Exact correct-count equality across M-tile tails (the count is an
    integer sum of per-tile integer partials — no tolerance)."""
    ks = jax.random.split(jax.random.key(3), 4)
    f, c = 33, 10
    feats = jax.random.normal(ks[0], (m, f))
    wmat = jax.random.normal(ks[1], (f, c)) * 0.1
    bias = jax.random.normal(ks[2], (c,)) * 0.1
    labels = jax.random.randint(ks[3], (m,), 0, c)
    got = eval_head(feats, wmat, bias, labels, interpret=True)
    ref = eval_head_ref(feats, wmat, bias, labels)
    assert got.dtype == jnp.int32
    assert int(got) == int(ref)


@pytest.mark.kernel_oracle
def test_eval_head_bf16_inputs():
    """bf16 feats/weights: both paths cast to f32 before the identical
    matmul, so the argmax — and the count — must agree exactly."""
    ks = jax.random.split(jax.random.key(4), 4)
    m, f, c = 70, 21, 5
    feats = jax.random.normal(ks[0], (m, f), jnp.bfloat16)
    wmat = (jax.random.normal(ks[1], (f, c)) * 0.2).astype(jnp.bfloat16)
    bias = (jax.random.normal(ks[2], (c,)) * 0.2).astype(jnp.bfloat16)
    labels = jax.random.randint(ks[3], (m,), 0, c)
    got = eval_head(feats, wmat, bias, labels, interpret=True)
    ref = eval_head_ref(feats, wmat, bias, labels)
    assert int(got) == int(ref)


@pytest.mark.kernel_oracle
@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 8),
       l=st.sampled_from([1, 37, CTILE - 1, CTILE, CTILE + 5]),
       seed=st.integers(0, 99))
def test_coef_agg_matches_ref_on_tile_tails(n, l, seed):
    ks = jax.random.split(jax.random.key(seed), 4)
    w = jax.random.normal(ks[0], (n, l))
    aux = jax.random.normal(ks[1], (n, l))
    coef = jax.nn.softmax(jax.random.normal(ks[2], (n,)))
    msk = (jax.random.uniform(ks[3], (n,)) > 0.4).astype(jnp.float32)
    got = coef_agg(w, coef, interpret=True)
    ref = coef_agg_ref(w, coef)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)
    got_p = coef_agg_pair(w, aux, coef * msk, coef * (1.0 - msk),
                          interpret=True)
    ref_p = coef_agg_pair_ref(w, aux, coef * msk, coef * (1.0 - msk))
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(ref_p),
                               atol=1e-6)


@pytest.mark.kernel_oracle
def test_coef_agg_bf16_storage_promotes_to_f32():
    """bf16 stacked weights with f32 coefficients: the aggregate is f32 on
    both paths (XLA's promotion rule), values within exact f32 math of the
    bf16 inputs."""
    w = jax.random.normal(jax.random.key(5), (4, 1000), jnp.bfloat16)
    coef = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    got = coef_agg(w, coef, interpret=True)
    ref = coef_agg_ref(w, coef)
    assert got.dtype == ref.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


@pytest.mark.kernel_oracle
def test_coef_agg_zero_coef_slots_are_exact_noops():
    """The padded-slot contract: a zero-coefficient row contributes exactly
    nothing, bitwise, whatever garbage it carries (0 * x == 0 in f32 for
    finite x)."""
    w_live = jax.random.normal(jax.random.key(6), (3, 500))
    garbage = jnp.full((2, 500), 1e6)
    w_pad = jnp.concatenate([w_live, garbage])
    w_zero = jnp.concatenate([w_live, jnp.zeros((2, 500))])
    coef = jnp.asarray([0.5, 0.3, 0.2, 0.0, 0.0])
    a = coef_agg(w_pad, coef, interpret=True)
    b = coef_agg(w_zero, coef, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- dispatch entry parity
@pytest.mark.kernel_oracle
def test_dispatch_cold_aggregates_match_hieavg_references():
    """The cold-boot dispatch entries (generalized coefficient aggregate)
    against ``core.hieavg`` — including an all-invalid edge, which must
    aggregate to exact zeros on both paths, and padded garbage slots."""
    ks = jax.random.split(jax.random.key(7), 2)
    w = {"a": jax.random.normal(ks[0], (3, 4, 5, 3)),
         "b": jax.random.normal(ks[1], (3, 4, 17))}
    valid = jnp.asarray([[1, 1, 1, 0], [0, 0, 0, 0], [1, 1, 1, 1]], bool)
    got = kd.edge_aggregate_cold_batched(w, valid, mode="interpret")
    ref = hieavg.edge_aggregate_cold_batched(w, valid)
    for k in w:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-6)
    wg = {"p": jax.random.normal(jax.random.key(8), (3, 7, 2))}
    j_arr = jnp.asarray([3.0, 2.0, 4.0])
    got_g = kd.global_aggregate_cold(wg, j_arr, mode="interpret")
    ref_g = hieavg.global_aggregate_cold(wg, j_arr)
    np.testing.assert_allclose(np.asarray(got_g["p"]),
                               np.asarray(ref_g["p"]), atol=1e-6)


@pytest.mark.kernel_oracle
def test_dispatch_baseline_aggregates_match_references():
    """``kd.fedavg`` / ``kd.delayed_grad`` against ``core.baselines`` —
    same coefficients, same staleness discount, same store updates."""
    ks = jax.random.split(jax.random.key(9), 3)
    w = {"p": jax.random.normal(ks[0], (5, 11, 3)),
         "q": jax.random.normal(ks[1], (5, 40))}
    pw = jnp.asarray([2.0, 1.0, 3.0, 0.0, 0.0])   # padded slots: zero weight
    got = kd.fedavg(w, pw, mode="interpret")
    ref = baselines.fedavg(w, pw)
    for k in w:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-6)

    pending = jax.tree.map(lambda x: x * 0.9 + 0.05, w)
    mask = jnp.asarray([True, False, True, False, True])
    age = jnp.asarray([0.0, 1.0, 0.0, 4.0, 2.0])
    beta, delta = jnp.float32(0.5), jnp.float32(3.0)
    a_got, p_got, age_got = kd.delayed_grad(w, mask, pending, age, beta,
                                            delta, pw, mode="interpret")
    a_ref, p_ref, age_ref = baselines.delayed_grad(w, mask, pending, age,
                                                   beta, delta, pw)
    for k in w:
        np.testing.assert_allclose(np.asarray(a_got[k]),
                                   np.asarray(a_ref[k]), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(p_got[k]),
                                      np.asarray(p_ref[k]))
    np.testing.assert_array_equal(np.asarray(age_got), np.asarray(age_ref))


@pytest.mark.kernel_oracle
def test_dispatch_conv_eval_interpret_matches_xla_branch():
    """The two train/eval dispatch entries: interpret vs the xla branch
    (which is the engine's original conv/eval chain, bit-for-bit)."""
    ks = jax.random.split(jax.random.key(10), 3)
    x = jax.random.normal(ks[0], (2, 8, 8, 3))
    w = jax.random.normal(ks[1], (3, 3, 3, 6)) * 0.3
    b = jax.random.normal(ks[2], (6,)) * 0.3
    np.testing.assert_allclose(
        np.asarray(kd.conv3x3_bias_relu(x, w, b, mode="interpret")),
        np.asarray(kd.conv3x3_bias_relu(x, w, b, mode="xla")), atol=1e-5)

    ks = jax.random.split(jax.random.key(11), 4)
    feats = jax.random.normal(ks[0], (50, 20))
    wmat = jax.random.normal(ks[1], (20, 10)) * 0.1
    bias = jax.random.normal(ks[2], (10,)) * 0.1
    labels = jax.random.randint(ks[3], (50,), 0, 10)
    assert int(kd.eval_head(feats, wmat, bias, labels, mode="interpret")) \
        == int(kd.eval_head(feats, wmat, bias, labels, mode="xla"))


# ------------------------------------------------------------ engine parity
def test_engine_kernel_plane_matches_xla_standalone():
    """The acceptance pin: fused-kernel engine == pure-XLA engine on a
    standalone run (same inputs, same trajectories)."""
    a = _sim(kernel_mode="xla").run()
    b = _sim(kernel_mode="interpret").run()
    _close(a, b)
    np.testing.assert_allclose(b.sim_clock, a.sim_clock, rtol=1e-6)


def test_engine_kernel_plane_bf16_history():
    a = _sim(kernel_mode="xla", history_dtype=jnp.bfloat16).run()
    b = _sim(kernel_mode="interpret", history_dtype=jnp.bfloat16).run()
    _close(a, b, acc_atol=0.02)
    np.testing.assert_allclose(b.loss, a.loss, rtol=1e-3, atol=1e-4)


def test_auto_mode_on_cpu_is_bitwise_the_xla_engine():
    """On CPU the default must add literally nothing: "auto" and "xla"
    resolve to the same jit cache entry and the same numbers."""
    a = _sim(kernel_mode="auto").run()
    b = _sim(kernel_mode="xla").run()
    np.testing.assert_array_equal(a.accuracy, b.accuracy)
    np.testing.assert_array_equal(a.loss, b.loss)


def test_sweep_kernel_plane_parity_multibucket():
    """The acceptance pin, sweep edition: a padded multi-bucket
    shape-changing grid through the fused kernels == the pure-XLA grid
    per point, including padded points and the simulated clock."""
    ovs = [{"n_edges": 2}, {"k_edge_rounds": 1}, {"t_global_rounds": 2},
           {}]
    plan_x = plan_sweep(TINY, overrides=ovs, kernel_mode="xla",
                        max_buckets=2, bucket_waste=1.0, **KW)
    plan_i = plan_sweep(TINY, overrides=ovs, kernel_mode="interpret",
                        max_buckets=2, bucket_waste=1.0, **KW)
    assert plan_x.kernel_mode == "xla"
    assert plan_i.kernel_mode == "interpret"
    assert len(plan_i.buckets) == 2
    sx, si = run_plan(plan_x), run_plan(plan_i)
    _close(sx, si)
    np.testing.assert_allclose(si.sim_clock, sx.sim_clock, rtol=1e-5)
    # ...and against standalone engine runs that never saw the fabric
    for p, (ov, seed) in enumerate(si.points):
        s = dataclasses.replace(TINY, **ov)
        r = BHFLSimulator(s, "hieavg", "temporary", "temporary", seed=seed,
                          kernel_mode="xla", **KW).run()
        tv = int(si.t_valid[p])
        np.testing.assert_allclose(si.accuracy[p, :tv], r.accuracy,
                                   atol=1e-6)
        np.testing.assert_allclose(si.loss[p, :tv], r.loss, rtol=1e-5,
                                   atol=1e-6)


def test_sweep_mixed_aggregation_kernel_plane_parity():
    """The acceptance pin, mixed-aggregation edition: hieavg, delayed_grad
    and fedavg points compile as ONE traced-"switched" program across a
    bucketed shape-changing grid, and the fused kernels must reproduce
    the pure-XLA grid per point — every aggregation dispatch entry
    (warm, cold, fedavg, delayed-grad) exercised inside one scan.
    ``bucket_cost="proxy"`` on both plans so the grids bucket identically
    and the comparison is point-for-point by construction."""
    ovs = [{"aggregation": "fedavg"}, {"aggregation": "delayed_grad"},
           {"n_edges": 2}, {}]
    kwb = dict(overrides=ovs, max_buckets=2, bucket_waste=1.0,
               bucket_cost="proxy", **KW)
    plan_x = plan_sweep(TINY, kernel_mode="xla", **kwb)
    plan_i = plan_sweep(TINY, kernel_mode="interpret", **kwb)
    assert plan_x.aggregator == plan_i.aggregator == "switched"
    sx, si = run_plan(plan_x), run_plan(plan_i)
    _close(sx, si)
    np.testing.assert_allclose(si.sim_clock, sx.sim_clock, rtol=1e-5)


# ---------------------------------------------------------------- donation
def test_donated_engine_matches_non_donated():
    """Donation smoke: same numbers, data plane never consumed."""
    inp_a = build_inputs(_sim())
    inp_b = build_inputs(_sim())
    a = run_engine(inp_a)
    b = run_engine_donated(inp_b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # the seed-major data plane is aliased by design and must survive
    assert not inp_b.train_x.is_deleted()
    assert not jax.tree.leaves(inp_b.init_w)[0].is_deleted()
    # the non-donated entry leaves everything reusable
    c = run_engine(inp_a)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(c[0]))


def test_split_inputs_partition():
    """Every EngineInputs field lands on exactly one side; the shared
    side is exactly the data plane (+ seed_idx when plan-wide)."""
    inp = build_inputs(_sim())
    hot, shared = split_inputs(inp)
    assert set(shared) == SHARED_DATA_FIELDS
    hot2, shared2 = split_inputs(inp, shared_seed_idx=True)
    assert set(shared2) == SHARED_DATA_FIELDS | {"seed_idx"}
    assert not (set(hot) & set(shared))
    assert set(hot) | set(shared) == set(hot2) | set(shared2)


def test_donated_plan_matches_and_is_consumed_once():
    ovs = [{"straggler_frac": 0.4}, {}]
    ref = run_sweep(TINY, overrides=ovs, **KW)        # fresh plan per call
    plan = plan_sweep(TINY, overrides=ovs, **KW)
    got = run_plan(plan)                              # donate=True default
    np.testing.assert_array_equal(got.accuracy, ref.accuracy)
    np.testing.assert_array_equal(got.loss, ref.loss)
    assert all(b.inputs is None for b in plan.buckets)
    with pytest.raises(ValueError, match="consumed"):
        run_plan(plan)
    with pytest.raises(ValueError, match="consumed"):
        plan.inputs          # the single-bucket accessor raises too
    # donate=False keeps a plan re-runnable, same numbers both times
    plan2 = plan_sweep(TINY, overrides=ovs, **KW)
    r1 = run_plan(plan2, donate=False)
    r2 = run_plan(plan2, donate=False)
    assert all(b.inputs is not None for b in plan2.buckets)
    np.testing.assert_array_equal(r1.accuracy, ref.accuracy)
    np.testing.assert_array_equal(r2.accuracy, ref.accuracy)
