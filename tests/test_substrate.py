"""Substrate layers: data pipeline, optimizer, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import by_class, class_images, dirichlet, lm_tokens
from repro.optim import adam_init, adam_step, paper_lr, sgd_init, sgd_step


# -------------------------------------------------------------------- data
def test_class_images_shapes_and_determinism():
    x1, y1 = class_images(100, seed=7, hw=14)
    x2, y2 = class_images(100, seed=7, hw=14)
    assert x1.shape == (100, 14, 14, 1) and y1.shape == (100,)
    np.testing.assert_array_equal(x1, x2)
    assert set(np.unique(y1)) <= set(range(10))


@settings(max_examples=8, deadline=None)
@given(max_classes=st.integers(1, 3), seed=st.integers(0, 30))
def test_by_class_partition_properties(max_classes, seed):
    _, labels = class_images(600, seed=seed)
    parts = by_class(labels, 3, [2, 3, 2], max_classes=max_classes,
                     seed=seed)
    assert len(parts) == 3 and [len(p) for p in parts] == [2, 3, 2]
    all_idx = np.concatenate([i for e in parts for i in e])
    assert len(all_idx) == len(set(all_idx)), "device shards must be disjoint"
    for edge in parts:
        for idx in edge:
            if len(idx):
                assert len(np.unique(labels[idx])) <= max_classes


def test_dirichlet_partition_disjoint():
    _, labels = class_images(500, seed=1)
    parts = dirichlet(labels, 2, [3, 3], alpha=0.5, seed=1)
    all_idx = np.concatenate([i for e in parts for i in e])
    assert len(all_idx) == len(set(all_idx))


def test_lm_tokens_in_vocab():
    t = lm_tokens(4, 64, vocab=50, seed=0)
    assert t.shape == (4, 64) and t.min() >= 0 and t.max() < 50


# ------------------------------------------------------------------- optim
def test_paper_lr_decays_from_eta0():
    lr0 = paper_lr(jnp.asarray(0), 1e-3, 0.9)
    lr9 = paper_lr(jnp.asarray(9), 1e-3, 0.9)
    assert abs(float(lr0) - 1e-3) < 1e-9
    assert float(lr9) < float(lr0)


def test_sgd_momentum_accumulates():
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.ones(3)}
    st_ = sgd_init(p)
    p1, st_ = sgd_step(p, g, st_, jnp.float32(0.1), momentum=0.9)
    p2, st_ = sgd_step(p1, g, st_, jnp.float32(0.1), momentum=0.9)
    # second step is larger due to momentum
    assert float(p1["w"][0] - p2["w"][0]) > float(1.0 - p1["w"][0])


def test_adam_converges_on_quadratic():
    p = {"w": jnp.asarray(5.0)}
    st_ = adam_init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st_ = adam_step(p, g, st_, jnp.float32(0.1))
    assert abs(float(p["w"])) < 0.1


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    d = str(tmp_path)
    save_checkpoint(d, 3, tree, metadata={"round": 3})
    save_checkpoint(d, 7, tree, metadata={"round": 7})
    assert latest_step(d) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = restore_checkpoint(d, like)
    assert meta == {"round": 7}
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_structure_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, {"a": jnp.ones(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"a": jnp.ones(2), "b": jnp.ones(1)})


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, {"a": jnp.ones(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"a": jnp.ones(3)})
