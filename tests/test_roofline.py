"""Unit tests for the roofline accounting (benchmarks/roofline.py)."""
import pytest

from benchmarks.roofline import active_params, model_flops, roofline_row
from repro.configs import get_config
from repro.models import count_params, param_specs


def test_active_params_dense_equals_total():
    total = count_params(param_specs(get_config("deepseek-7b")))
    assert active_params("deepseek-7b") == pytest.approx(total)


def test_active_params_moe_less_than_total():
    """Grok: 8 experts top-2 -> routed compute is 1/4 of routed params."""
    total = count_params(param_specs(get_config("grok-1-314b")))
    act = active_params("grok-1-314b")
    assert act < total
    # routed fraction dominates grok: active should be well under half
    assert act / total < 0.5


def test_model_flops_shapes():
    # train = 6*N*tokens; prefill = 2*N*tokens; decode = 2*N*batch
    n = active_params("h2o-danube-1.8b")
    assert model_flops("h2o-danube-1.8b", "train_4k") == pytest.approx(
        6.0 * n * 256 * 4096)
    assert model_flops("h2o-danube-1.8b", "prefill_32k") == pytest.approx(
        2.0 * n * 32 * 32768)
    assert model_flops("h2o-danube-1.8b", "decode_32k") == pytest.approx(
        2.0 * n * 128)


def test_roofline_row_dominant_term():
    rec = {
        "arch": "h2o-danube-1.8b", "shape": "decode_32k", "mesh": "16x16",
        "flops": 1e9, "hlo_bytes": 1e9,
        "collectives": {"total_bytes": 5e10},
        "bytes_per_device": 2**30,
    }
    row = roofline_row(rec)
    assert row["chips"] == 256
    assert row["dominant"] == "collective"     # 1 s vs tiny others
    assert row["t_collective_s"] == pytest.approx(1.0)
    assert 0.0 <= row["roofline_frac"] <= 1.0


def test_roofline_row_scan_correction_bounded():
    """The memory-term scan-body correction is clamped to [1, 128]."""
    rec = {
        "arch": "deepseek-7b", "shape": "train_4k", "mesh": "2x16x16",
        "flops": 1.0,            # absurdly small -> scale would explode
        "hlo_bytes": 1e9,
        "collectives": {"total_bytes": 0},
        "bytes_per_device": 0,
    }
    row = roofline_row(rec)
    from repro.launch.mesh import HBM_BW
    assert row["t_memory_s"] <= 128.0 * 1e9 / HBM_BW + 1e-9
