"""The fully-jitted batched engine matches the legacy per-edge loop.

``BHFLSimulator.run`` (engine) and ``BHFLSimulator.run_legacy`` (original
Python loop) consume the same seeds, schedules, and batch-sampling order, so
their trajectories must agree.  The engine trains with the im2col conv
(``cnn_loss_fast``) — same math as the legacy shifted-sum conv up to float32
summation order — so trajectories are compared within tolerance, not
bitwise.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import BHFLSimulator

TINY = dataclasses.replace(REDUCED, t_global_rounds=4, n_edges=3,
                           j_per_edge=3, image_hw=8)
KW = dict(n_train=300, n_test=100, steps_per_epoch=2)

ACC_TOL = 0.02     # accuracy is a discrete metric: borderline test samples
LOSS_TOL = 1e-3    # may flip under reordered float32 sums


def _pair(agg, strag="temporary", setting=TINY, **kw):
    a = BHFLSimulator(setting, agg, strag, strag, **KW, **kw).run_legacy()
    b = BHFLSimulator(setting, agg, strag, strag, **KW, **kw).run()
    return a, b


def _check(a, b):
    np.testing.assert_allclose(b.accuracy, a.accuracy, atol=ACC_TOL)
    np.testing.assert_allclose(b.loss, a.loss, rtol=LOSS_TOL, atol=LOSS_TOL)
    np.testing.assert_allclose(b.grad_norm, a.grad_norm, rtol=0.01,
                               atol=1e-4)
    assert b.blocks == a.blocks
    assert b.chain_valid and a.chain_valid


@pytest.mark.parametrize("agg", ["hieavg", "t_fedavg", "d_fedavg", "fedavg",
                                 "delayed_grad"])
def test_parity_all_aggregators(agg):
    strag = "none" if agg == "fedavg" else "temporary"
    _check(*_pair(agg, strag))


def test_parity_ragged_j_per_edge():
    """Dense [N, J_max] padding must not perturb ragged deployments."""
    _check(*_pair("hieavg", j_per_edge=[2, 3, 4]))


def test_parity_permanent_normalized():
    s = dataclasses.replace(TINY, permanent_stop_round=1)
    _check(*_pair("hieavg", "permanent", setting=s, normalize=True))


def test_parity_leader_failover():
    s = dataclasses.replace(TINY, t_global_rounds=6)
    a, b = _pair("hieavg", setting=s, normalize=True, fail_leader_at=3)
    _check(a, b)
    assert a.blocks == 6 and b.blocks == 6


def test_engine_run_is_deterministic():
    """run() re-seeds its batch RNG: two engine runs of equal-seed sims
    (fresh instances) are identical."""
    r1 = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW).run()
    r2 = BHFLSimulator(TINY, "hieavg", "temporary", "temporary", **KW).run()
    np.testing.assert_array_equal(r1.accuracy, r2.accuracy)
    np.testing.assert_array_equal(r1.loss, r2.loss)


def test_repeated_run_with_failover_is_stable():
    """A second run() on a fail_leader_at simulator must replay the SAME
    crashed edge (not kill another leader and lose Raft quorum)."""
    s = dataclasses.replace(TINY, t_global_rounds=3)
    sim = BHFLSimulator(s, "hieavg", "temporary", "temporary",
                        fail_leader_at=2, **KW)
    r1 = sim.run()
    r2 = sim.run()
    np.testing.assert_array_equal(r1.accuracy, r2.accuracy)
    assert int(sim.chain.alive.sum()) == sim.N - 1  # exactly one crash
    assert r2.chain_valid


def test_run_sweep_matches_single_runs():
    """One vmapped grid call reproduces the individual engine runs."""
    from repro.fl import run_sweep

    sw = run_sweep(TINY, seeds=(0, 1),
                   overrides=[{"straggler_frac": 0.2}], **KW)
    assert sw.accuracy.shape == (2, TINY.t_global_rounds)
    for p, (_, seed) in enumerate(sw.points):
        r = BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                          seed=seed, **KW).run()
        np.testing.assert_allclose(sw.accuracy[p], r.accuracy, atol=1e-6)
        np.testing.assert_allclose(sw.loss[p], r.loss, rtol=1e-5, atol=1e-6)
