"""Unit tests for the dry-run's HLO static analysis (while-aware census).

These run without the 512-device environment — they exercise the pure
text-parsing layer on synthetic HLO, so census regressions are caught by
the normal suite rather than only by a 40-minute sweep.
"""
import textwrap

from repro.launch.dryrun import (_computation_multipliers,
                                 _split_computations, _tensor_bytes,
                                 collective_census)

HLO = textwrap.dedent("""\
    HloModule jit_step

    %cond.1 (arg.1: (s32[], f32[8,128])) -> pred[] {
      %p = (s32[], f32[8,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %c = s32[] constant(30)
      ROOT %cmp = pred[] compare(%i, %c), direction=LT
    }

    %body.1 (arg.2: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
      %p2 = (s32[], f32[8,128]) parameter(0)
      %x = f32[8,128] get-tuple-element(%p2), index=1
      %ar = f32[8,128] all-reduce(%x), replica_groups={}, to_apply=%add.1
      %i2 = s32[] get-tuple-element(%p2), index=0
      ROOT %t = (s32[], f32[8,128]) tuple(%i2, %ar)
    }

    %add.1 (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main.1 (arg.0: f32[8,128]) -> f32[8,128] {
      %a0 = f32[8,128] parameter(0)
      %ag = f32[8,128] all-gather(%a0), replica_groups={}, dimensions={0}
      %init = (s32[], f32[8,128]) tuple(%zero, %ag)
      %w = (s32[], f32[8,128]) while(%init), condition=%cond.1, body=%body.1
      ROOT %out = f32[8,128] get-tuple-element(%w), index=1
    }
    """)


def test_tensor_bytes():
    assert _tensor_bytes("f32[8,128]") == 8 * 128 * 4
    assert _tensor_bytes("bf16[2,4]") == 2 * 4 * 2
    assert _tensor_bytes("(f32[4], bf16[4])") == 16 + 8
    assert _tensor_bytes("pred[]") == 1


def test_split_computations():
    comps = _split_computations(HLO)
    assert set(comps) == {"cond.1", "body.1", "add.1", "main.1"}
    assert "all-reduce" in comps["body.1"]
    assert "all-gather" in comps["main.1"]


def test_while_multiplier_from_trip_count():
    comps = _split_computations(HLO)
    mult = _computation_multipliers(comps)
    assert mult["main.1"] == 1.0
    assert mult["body.1"] == 30.0      # trip count from the cond constant


def test_census_weights_loop_bodies():
    census = collective_census(HLO)
    leaf = 8 * 128 * 4
    assert census["all-gather"]["bytes"] == leaf          # entry: x1
    assert census["all-reduce"]["bytes"] == 30 * leaf     # body: x30
    assert census["total_bytes"] == 31 * leaf
