"""``_mix_and_update`` (fused aggregate + history update) must agree with
the composed reference path ``_mix`` + ``update_history``, and the batched
(vmapped, validity-masked) entry points must agree with the per-edge API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hieavg


def stacked(n, shapes=((3, 4), (5,)), seed=0, scale=1.0):
    ks = jax.random.split(jax.random.key(seed), len(shapes))
    return {f"p{i}": jax.random.normal(k, (n,) + s) * scale
            for i, (k, s) in enumerate(zip(ks, shapes))}


def tree_close(a, b, **kw):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), **kw)


def warmed_history(n, seed=0):
    """Two observed rounds so delta stats are non-trivial."""
    w0 = stacked(n, seed=seed)
    hist = hieavg.init_history(w0)
    w1 = stacked(n, seed=seed + 1)
    hist = hieavg.update_history(hist, w1, jnp.ones(n, bool))
    return hist


@pytest.mark.parametrize("normalize", [False, True])
@pytest.mark.parametrize("mask", [(True,) * 4, (True, False, True, False),
                                  (False,) * 4])
def test_fused_matches_composed(normalize, mask):
    n = 4
    hist = warmed_history(n)
    w = stacked(n, seed=7)
    m = jnp.asarray(mask)
    pw = jnp.full((n,), 1.0 / n, jnp.float32)

    agg_ref = hieavg._mix(w, m, hist, pw, 0.9, 0.8, normalize)
    hist_ref = hieavg.update_history(hist, w, m)
    agg, hist_new = hieavg._mix_and_update(w, m, hist, pw, 0.9, 0.8,
                                           normalize)

    tree_close(agg, agg_ref, rtol=1e-5, atol=1e-6)
    tree_close(hist_new.prev_w, hist_ref.prev_w, rtol=1e-5, atol=1e-6)
    tree_close(hist_new.delta_mean, hist_ref.delta_mean, rtol=1e-5,
               atol=1e-6)
    np.testing.assert_allclose(hist_new.n_obs, hist_ref.n_obs)
    np.testing.assert_allclose(hist_new.miss_count, hist_ref.miss_count)


def test_multi_round_consecutive_miss_decay():
    """Fused and composed paths stay in lockstep over consecutive misses,
    and the straggler slot's decay factor follows gamma0 * lam**k'."""
    n, g0, lam = 3, 0.9, 0.7
    hist_f = warmed_history(n)
    hist_c = warmed_history(n)
    pw = jnp.full((n,), 1.0 / n, jnp.float32)
    for rnd in range(1, 5):
        w = stacked(n, seed=10 + rnd)
        m = jnp.asarray([False, True, True])   # participant 0 keeps missing
        agg_f, hist_f = hieavg._mix_and_update(w, m, hist_f, pw, g0, lam,
                                               False)
        agg_c = hieavg._mix(w, m, hist_c, pw, g0, lam, False)
        hist_c = hieavg.update_history(hist_c, w, m)
        tree_close(agg_f, agg_c, rtol=1e-5, atol=1e-6)
        assert float(hist_f.miss_count[0]) == rnd  # k' grows per missed round
        assert float(hist_f.miss_count[1]) == 0.0


def test_multi_round_decay_normalized():
    """Same lockstep under normalize=True (affine-combination mode)."""
    n = 3
    hist_f, hist_c = warmed_history(n, seed=3), warmed_history(n, seed=3)
    pw = jnp.full((n,), 1.0 / n, jnp.float32)
    for rnd in range(1, 4):
        w = stacked(n, seed=20 + rnd)
        m = jnp.asarray([False, False, True])
        agg_f, hist_f = hieavg._mix_and_update(w, m, hist_f, pw, 0.9, 0.9,
                                               True)
        agg_c = hieavg._mix(w, m, hist_c, pw, 0.9, 0.9, True)
        hist_c = hieavg.update_history(hist_c, w, m)
        tree_close(agg_f, agg_c, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- batched entry API
def test_edge_aggregate_batched_matches_per_edge():
    """vmapped dense aggregation == looped per-edge ``edge_aggregate``."""
    n_edges, j = 3, 4
    w = {"p": jax.random.normal(jax.random.key(0), (n_edges, j, 2, 3))}
    mask = jax.random.bernoulli(jax.random.key(1), 0.6, (n_edges, j))
    hist_b = hieavg.init_history_batched(w)
    # warm one observed round
    w1 = {"p": jax.random.normal(jax.random.key(2), (n_edges, j, 2, 3))}
    hist_b = hieavg.update_history_batched(hist_b, w1, jnp.ones((n_edges, j),
                                                                bool))
    valid = jnp.ones((n_edges, j), bool)
    agg_b, new_b = hieavg.edge_aggregate_batched(w1, mask, hist_b, valid,
                                                 0.9, 0.9)
    for e in range(n_edges):
        we = {"p": w1["p"][e]}
        he = jax.tree.map(lambda x: x[e], hist_b)
        agg_e, new_e = hieavg.edge_aggregate(we, mask[e], he)
        tree_close({"p": agg_b["p"][e]}, agg_e, rtol=1e-5, atol=1e-6)
        tree_close(jax.tree.map(lambda x: x[e], new_b), new_e, rtol=1e-5,
                   atol=1e-6)


def test_edge_aggregate_batched_padding_is_inert():
    """Padded slots (valid=False) must not change the real slots' result."""
    n_edges, j = 2, 3
    w_r = {"p": jax.random.normal(jax.random.key(0), (n_edges, j, 5))}
    mask_r = jnp.asarray([[True, False, True], [True, True, False]])
    hist_r = hieavg.init_history_batched(w_r)
    valid_r = jnp.ones((n_edges, j), bool)
    agg_r, _ = hieavg.edge_aggregate_batched(w_r, mask_r, hist_r, valid_r,
                                             0.9, 0.9)
    # same data embedded in a wider padded layout with garbage in the pad
    pad = 99.0 * jnp.ones((n_edges, 2, 5))
    w_p = {"p": jnp.concatenate([w_r["p"], pad], axis=1)}
    mask_p = jnp.concatenate(
        [mask_r, jnp.zeros((n_edges, 2), bool)], axis=1)
    valid_p = jnp.concatenate(
        [valid_r, jnp.zeros((n_edges, 2), bool)], axis=1)
    hist_p = hieavg.init_history_batched(w_p)
    agg_p, _ = hieavg.edge_aggregate_batched(w_p, mask_p, hist_p, valid_p,
                                             0.9, 0.9)
    tree_close(agg_p, agg_r, rtol=1e-5, atol=1e-6)


def test_edge_aggregate_cold_batched_masked_mean():
    n_edges, j = 2, 4
    w = {"p": jax.random.normal(jax.random.key(5), (n_edges, j, 3))}
    valid = jnp.asarray([[True, True, True, False],
                         [True, True, False, False]])
    agg = hieavg.edge_aggregate_cold_batched(w, valid)
    for e, je in enumerate((3, 2)):
        np.testing.assert_allclose(
            np.asarray(agg["p"][e]),
            np.asarray(jnp.mean(w["p"][e, :je], axis=0)), rtol=1e-5)
