"""Pallas kernel validation: interpret=True vs pure-jnp oracles,
hypothesis shape/dtype sweeps."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hieavg
from repro.kernels.flash_attention import flash_attention_1h
from repro.kernels.hieavg_agg import hieavg_agg
from repro.kernels.ops import flash_attention, fused_edge_aggregate
from repro.kernels.ref import flash_attention_ref, hieavg_agg_ref
from repro.models.attention import _sdpa


# -------------------------------------------------------------- hieavg_agg
@settings(max_examples=12, deadline=None)
@given(n=st.integers(2, 9), l=st.sampled_from([64, 1000, 2048, 3000]),
       dt=st.sampled_from(["float32", "bfloat16"]), seed=st.integers(0, 99))
def test_hieavg_agg_matches_ref(n, l, dt, seed):
    dt = jnp.dtype(dt)
    ks = jax.random.split(jax.random.key(seed), 6)
    w = jax.random.normal(ks[0], (n, l), dt)
    prev = jax.random.normal(ks[1], (n, l), dt)
    dmean = jax.random.normal(ks[2], (n, l), dt) * 0.1
    mask = jax.random.bernoulli(ks[3], 0.7, (n,))
    cp = jax.random.uniform(ks[4], (n,))
    ce = jax.random.uniform(ks[5], (n,)) * 0.3
    nobs = jnp.arange(n, dtype=jnp.float32)
    ref = hieavg_agg_ref(w, prev, dmean, mask, cp, ce, nobs)
    got = hieavg_agg(w, prev, dmean, mask, cp, ce, nobs)
    tol = 1e-5 if dt == jnp.float32 else 6e-2
    for r, g in zip(ref, got):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(r, np.float32), atol=tol)


def test_fused_edge_aggregate_matches_core():
    """ops.fused_edge_aggregate == core hieavg.edge_aggregate end to end."""
    n = 5
    stacked = {"a": jax.random.normal(jax.random.key(0), (n, 13, 7)),
               "b": jax.random.normal(jax.random.key(1), (n, 40))}
    hist = hieavg.init_history(stacked)
    hist = dataclasses.replace(
        hist,
        delta_mean=jax.tree.map(lambda x: x * 0.05, stacked),
        n_obs=jnp.full((n,), 3.0),
        miss_count=jnp.array([0.0, 1.0, 0.0, 2.0, 0.0]))
    mask = jnp.array([True, False, True, False, True])
    for normalize in (False, True):
        agg_ref, h_ref = hieavg.edge_aggregate(stacked, mask, hist,
                                               normalize=normalize)
        agg_got, h_got = fused_edge_aggregate(stacked, mask, hist,
                                              normalize=normalize)
        for k in stacked:
            np.testing.assert_allclose(np.asarray(agg_got[k]),
                                       np.asarray(agg_ref[k]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(h_got.prev_w[k]),
                                       np.asarray(h_ref.prev_w[k]),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(h_got.delta_mean[k]),
                                       np.asarray(h_ref.delta_mean[k]),
                                       atol=1e-5)
        np.testing.assert_array_equal(np.asarray(h_got.n_obs),
                                      np.asarray(h_ref.n_obs))
        np.testing.assert_array_equal(np.asarray(h_got.miss_count),
                                      np.asarray(h_ref.miss_count))


# ----------------------------------------------------------------- flash
@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([1, 128, 300, 512]),
       skv=st.sampled_from([256, 300, 512]),
       d=st.sampled_from([64, 80, 128]),
       causal=st.booleans(), seed=st.integers(0, 50))
def test_flash_1h_matches_ref(sq, skv, d, causal, seed):
    if causal and sq > skv:
        sq = skv
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (skv, d), jnp.float32)
    v = jax.random.normal(ks[2], (skv, d), jnp.float32)
    off = skv - sq if causal else 0
    ref = flash_attention_ref(q, k, v, causal=causal, q_offset=off)
    got = flash_attention_1h(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(window=st.sampled_from([32, 100, 256]), seed=st.integers(0, 20))
def test_flash_1h_sliding_window(window, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (512, 64), jnp.float32)
    k = jax.random.normal(ks[1], (512, 64), jnp.float32)
    v = jax.random.normal(ks[2], (512, 64), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    got = flash_attention_1h(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_gqa_matches_sdpa():
    q = jax.random.normal(jax.random.key(0), (2, 384, 8, 64))
    k = jax.random.normal(jax.random.key(1), (2, 384, 2, 64))
    v = jax.random.normal(jax.random.key(2), (2, 384, 2, 64))
    ref = _sdpa(q, k, v, causal=True, window=None)
    got = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_bf16():
    q = jax.random.normal(jax.random.key(0), (1, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (1, 256, 4, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (1, 256, 4, 64), jnp.bfloat16)
    ref = _sdpa(q, k, v, causal=True, window=None)
    got = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_flash_backend_switch_matches_xla_path():
    """models/attention with USE_FLASH_KERNEL routes through the Pallas
    kernel and must reproduce the XLA chunked path end to end."""
    import repro.models.attention as att
    from repro.configs import get_smoke
    from repro.models import forward_train, init_from_specs, param_specs

    cfg = get_smoke("h2o-danube-1.8b")
    params = init_from_specs(param_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab)
    ref, _ = forward_train(params, toks, cfg)
    att.USE_FLASH_KERNEL = True
    try:
        got, _ = forward_train(params, toks, cfg)
    finally:
        att.USE_FLASH_KERNEL = False
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-4)
