"""Sharding rules, input specs, and single-device lowering of the SPMD
steps (the 512-way production lowering is exercised by the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke
from repro.launch import (init_fl_histories, input_specs,
                          make_debug_mesh, make_hfl_train_step,
                          make_production_mesh, make_serve_step)
from repro.launch import sharding as shd
from repro.models import INPUT_SHAPES, init_from_specs, param_specs


def test_production_mesh_shapes():
    # uses however many host devices exist; only the *spec* is asserted via
    # the abstract mesh construction in the dry-run.  Here: the debug mesh.
    m = make_debug_mesh()
    assert tuple(m.axis_names) == ("data", "model")


def test_resolve_spec_divisibility_fallback():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # force extents via a fake mesh dict is awkward on 1 device; test the
    # pure logic through a synthetic mesh-like namespace instead
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = shd.resolve_spec((8, 128), ("kv_heads", None), shd.SERVE_RULES,
                            FakeMesh)
    assert spec == P()          # 8 kv heads don't divide 16 -> replicated
    spec = shd.resolve_spec((32, 128), ("kv_heads", None), shd.SERVE_RULES,
                            FakeMesh)
    assert spec == P("model")


def test_resolve_spec_secondary_kv_seq():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    # kv_heads=8 can't take model -> kv_seq picks it up in pass 2
    spec = shd.resolve_spec((128, 32768, 8, 128),
                            ("act_batch", "kv_seq", "kv_heads", None),
                            shd.SERVE_RULES, FakeMesh)
    assert spec == P("data", "model")
    # kv_heads=16 takes model first -> kv_seq replicated
    spec = shd.resolve_spec((128, 32768, 16, 128),
                            ("act_batch", "kv_seq", "kv_heads", None),
                            shd.SERVE_RULES, FakeMesh)
    assert spec == P("data", None, "model")


def test_resolve_no_axis_reuse_within_tensor():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = shd.resolve_spec((4096, 11008), ("mlp", "mlp"), shd.TRAIN_RULES,
                            FakeMesh)
    assert spec in (P("model"), P("model", None))  # second dim must not reuse


def test_train_input_specs_shapes():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    cfg = get_config("deepseek-7b")
    # use the real mesh api through input_specs requires NamedSharding ->
    # needs a real mesh; use the debug mesh for structure-only checks
    mesh = make_debug_mesh()
    specs = input_specs(cfg, INPUT_SHAPES["train_4k"], mesh)
    e, c = 1, cfg.clients_per_pod
    b = 256 // (e * c)
    assert specs["batch"]["tokens"].shape == (e, c, b, 4096)
    assert specs["dev_mask"].shape == (e, c)
    leaf = jax.tree.leaves(specs["params"])[0]
    assert leaf.shape[:2] == (e, c)


def test_serve_input_specs_decode():
    mesh = make_debug_mesh()
    cfg = get_config("minicpm3-4b")
    specs = input_specs(cfg, INPUT_SHAPES["decode_32k"], mesh)
    assert specs["token"].shape == (128, 1)
    c_kv = jax.tree.leaves(specs["caches"])[0]
    assert c_kv.shape[-2] == 32768 or c_kv.shape[-3] == 32768


def test_hfl_train_step_runs_single_device():
    """Full hierarchical step (local SGD + HieAvg edge + global agg) on the
    smoke arch, 1 device, E=1 C=2."""
    cfg = get_smoke("h2o-danube-1.8b")
    e, c, b, s = 1, 2, 2, 16
    key = jax.random.key(0)
    base = init_from_specs(param_specs(cfg), key)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (e, c) + x.shape), base)
    dev_hist, glob_hist = init_fl_histories(params)
    step = make_hfl_train_step(cfg)
    batch = {"tokens": jnp.zeros((e, c, b, s), jnp.int32),
             "labels": jnp.zeros((e, c, b, s), jnp.int32)}
    p2, dh2, gh2, loss = jax.jit(step)(
        params, dev_hist, glob_hist, batch,
        jnp.ones((e, c), bool), jnp.ones((e,), bool),
        jnp.float32(1e-3))
    assert np.isfinite(float(loss))
    # after a global round every client slot holds the same global model
    l0 = jax.tree.leaves(p2)[0]
    np.testing.assert_allclose(np.asarray(l0[0, 0]), np.asarray(l0[0, 1]),
                               rtol=1e-6)


def test_hfl_step_straggler_mask_changes_result():
    cfg = get_smoke("h2o-danube-1.8b")
    e, c, b, s = 1, 3, 2, 16
    base = init_from_specs(param_specs(cfg), jax.random.key(0))
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (e, c) + x.shape), base)
    dev_hist, glob_hist = init_fl_histories(params)
    # diverge client weights so masking matters
    params = jax.tree.map(
        lambda x: x * (1.0 + 0.1 * jnp.arange(c).reshape(1, c, *[1] *
                                                         (x.ndim - 2))),
        params)
    step = jax.jit(make_hfl_train_step(cfg))
    batch = {"tokens": jnp.zeros((e, c, b, s), jnp.int32),
             "labels": jnp.zeros((e, c, b, s), jnp.int32)}
    args = (dev_hist, glob_hist, batch)
    p_all, *_ = step(params, *args, jnp.ones((e, c), bool),
                     jnp.ones((e,), bool), jnp.float32(0.0))
    p_mask, *_ = step(params, *args,
                      jnp.array([[True, False, True]]),
                      jnp.ones((e,), bool), jnp.float32(0.0))
    diff = sum(float(jnp.abs(a - b).sum())
               for a, b in zip(jax.tree.leaves(p_all),
                               jax.tree.leaves(p_mask)))
    assert diff > 0.0


def test_serve_step_runs_single_device():
    from repro.models import cache_specs
    cfg = get_smoke("mamba2-130m")
    params = init_from_specs(param_specs(cfg), jax.random.key(0))
    caches = init_from_specs(cache_specs(cfg, 2, 32, dtype=jnp.float32),
                             jax.random.key(1))
    step = jax.jit(make_serve_step(cfg))
    logits, caches2 = step(params, jnp.zeros((2, 1), jnp.int32),
                           jnp.asarray(5, jnp.int32), caches)
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
