"""Tier-1 multi-device coverage for the sweep fabric's shard_map path.

jax fixes its device count at first import, so the 4-device run happens in
a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(the standard forced-host-device trick).  Inside, the same padded
shape-changing grid is executed via the single-device ``vmap`` path and
the ``shard_map``-over-``data`` path, and the two must agree; one point is
additionally pinned to a standalone engine run so the sharded numbers are
anchored to the reference, not just to each other.
"""
import os
import subprocess
import sys
import textwrap

_CHILD = textwrap.dedent("""
    import dataclasses
    import jax
    import numpy as np

    from repro.configs.bhfl_cnn import REDUCED
    from repro.fl import BHFLSimulator, run_sweep
    from repro.launch import make_sweep_mesh
    from repro.launch.sharding import sweep_spec
    from jax.sharding import PartitionSpec

    assert len(jax.devices()) == 4, jax.devices()
    mesh = make_sweep_mesh()
    assert sweep_spec(4, mesh) == PartitionSpec("data")
    assert sweep_spec(3, mesh) == PartitionSpec()   # indivisible -> vmap

    TINY = dataclasses.replace(REDUCED, t_global_rounds=3, n_edges=3,
                               j_per_edge=3, image_hw=8)
    KW = dict(n_train=300, n_test=100, steps_per_epoch=2)
    ovs = [{"n_edges": 2}, {"j_per_edge": 2}, {"k_edge_rounds": 1},
           {"straggler_frac": 0.4}]

    # forcing shard on a mixed-shape grid needs the single global-max
    # bucket: auto-bucketed sub-grids of 1-2 points cannot divide 4 devices
    a = run_sweep(TINY, overrides=ovs, placement="vmap", max_buckets=1,
                  **KW)
    b = run_sweep(TINY, overrides=ovs, placement="shard", max_buckets=1,
                  **KW)
    np.testing.assert_allclose(b.accuracy, a.accuracy, atol=1e-6)
    np.testing.assert_allclose(b.loss, a.loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b.grad_norm, a.grad_norm, rtol=1e-4,
                               atol=1e-6)

    s0 = dataclasses.replace(TINY, **ovs[0])
    r0 = BHFLSimulator(s0, "hieavg", "temporary", "temporary", **KW).run()
    np.testing.assert_allclose(b.accuracy[0], r0.accuracy, atol=1e-6)
    np.testing.assert_allclose(b.loss[0], r0.loss, rtol=1e-5, atol=1e-6)

    auto = run_sweep(TINY, overrides=ovs, placement="auto", **KW)
    np.testing.assert_allclose(auto.accuracy, b.accuracy, atol=1e-6)

    # seed-deduped data plane under real shard_map: 4 points over 2
    # distinct seeds shard across the 4 devices while the [2, ...] data
    # plane stays replicated and every shard gathers its row by seed_idx
    seeded_v = run_sweep(TINY, seeds=(0, 1),
                         overrides=[{}, {"straggler_frac": 0.4}],
                         placement="vmap", **KW)
    seeded_s = run_sweep(TINY, seeds=(0, 1),
                         overrides=[{}, {"straggler_frac": 0.4}],
                         placement="shard", **KW)
    np.testing.assert_allclose(seeded_s.accuracy, seeded_v.accuracy,
                               atol=1e-6)
    np.testing.assert_allclose(seeded_s.sim_clock, seeded_v.sim_clock,
                               rtol=1e-5)

    # kernel plane in the shard_map child: the fused kernels (Pallas
    # interpreter on these host devices) under real 4-way sharding must
    # reproduce the pure-XLA sharded grid per point (proxy bucketing: no
    # point timing interpret-mode steps just to pick bucket shapes)
    kp = run_sweep(TINY, overrides=ovs, placement="shard", max_buckets=1,
                   kernel_mode="interpret", bucket_cost="proxy", **KW)
    np.testing.assert_allclose(kp.accuracy, b.accuracy, atol=1e-6)
    np.testing.assert_allclose(kp.loss, b.loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(kp.sim_clock, b.sim_clock, rtol=1e-5)

    # ...and with the full dispatch surface: a mixed-aggregation grid
    # (hieavg + delayed_grad + fedavg = the traced "switched" program,
    # exercising the warm, cold, fedavg and delayed-grad kernel entries)
    # sharded 4-way, fused vs pure-XLA
    mix = [{"aggregation": "fedavg"}, {"aggregation": "delayed_grad"},
           {"straggler_frac": 0.4}, {}]
    mx = run_sweep(TINY, overrides=mix, placement="shard", max_buckets=1,
                   **KW)
    mi = run_sweep(TINY, overrides=mix, placement="shard", max_buckets=1,
                   kernel_mode="interpret", **KW)
    np.testing.assert_allclose(mi.accuracy, mx.accuracy, atol=1e-6)
    np.testing.assert_allclose(mi.loss, mx.loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mi.sim_clock, mx.sim_clock, rtol=1e-5)
    print("MULTIDEVICE_SWEEP_OK")
""")


def test_shard_map_agrees_with_vmap_on_four_host_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "MULTIDEVICE_SWEEP_OK" in proc.stdout
