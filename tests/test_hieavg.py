"""Unit + property tests for the paper's HieAvg aggregation (Sec. 3)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hieavg


def stacked(n, shapes=((3, 4), (5,)), seed=0, scale=1.0):
    ks = jax.random.split(jax.random.key(seed), len(shapes))
    return {f"p{i}": jax.random.normal(k, (n,) + s) * scale
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_cold_edge_aggregate_is_mean():
    w = stacked(5)
    agg = hieavg.edge_aggregate_cold(w)
    for k in w:
        np.testing.assert_allclose(agg[k], jnp.mean(w[k], axis=0), rtol=1e-6)


def test_cold_global_aggregate_weights_by_j():
    w = stacked(3)
    j = jnp.array([1.0, 2.0, 3.0])
    agg = hieavg.global_aggregate_cold(w, j)
    for k in w:
        expect = (w[k][0] * 1 + w[k][1] * 2 + w[k][2] * 3) / 6.0
        np.testing.assert_allclose(agg[k], expect, rtol=1e-5)


def test_full_mask_equals_plain_mean():
    """With no stragglers eq. (4) reduces to eq. (2)."""
    w = stacked(4)
    hist = hieavg.init_history(w)
    mask = jnp.ones(4, bool)
    agg, _ = hieavg.edge_aggregate(w, mask, hist)
    for k in w:
        np.testing.assert_allclose(np.asarray(agg[k]),
                                   np.asarray(jnp.mean(w[k], axis=0)),
                                   rtol=1e-5)


def test_straggler_estimate_uses_history():
    """A straggler's slot is γ(w_prev + Δ̄), γ = γ0·λ^k' (eq. 4)."""
    n = 2
    w = stacked(n, seed=1)
    prev = stacked(n, seed=2)
    dmean = stacked(n, seed=3, scale=0.1)
    hist = hieavg.History(prev_w=prev, delta_mean=dmean,
                          n_obs=jnp.full((n,), 2.0),
                          miss_count=jnp.zeros((n,)))
    mask = jnp.array([True, False])
    gamma0, lam = 0.9, 0.9
    agg, _ = hieavg.edge_aggregate(w, mask, hist, gamma0=gamma0, lam=lam)
    gamma = gamma0 * lam ** 1  # first miss: k' = 1
    for k in w:
        est = prev[k][1] + dmean[k][1]
        expect = (w[k][0] + gamma * est) / n
        np.testing.assert_allclose(np.asarray(agg[k]), np.asarray(expect),
                                   rtol=1e-5)


def test_decay_grows_with_consecutive_misses():
    n = 2
    w = stacked(n)
    hist = hieavg.init_history(w)
    mask = jnp.array([True, False])
    h = hist
    for expected_miss in (1.0, 2.0, 3.0):
        _, h = hieavg.edge_aggregate(w, mask, h)
        assert float(h.miss_count[1]) == expected_miss
        assert float(h.miss_count[0]) == 0.0


def test_returned_straggler_resets_miss_count():
    w = stacked(3)
    hist = hieavg.init_history(w)
    _, hist = hieavg.edge_aggregate(w, jnp.array([True, False, True]), hist)
    _, hist = hieavg.edge_aggregate(w, jnp.array([True, True, True]), hist)
    assert float(hist.miss_count[1]) == 0.0


def test_history_extrapolates_for_stragglers():
    """prev_w of a straggler advances by Δ̄ (multi-round estimation)."""
    n = 2
    prev = stacked(n, seed=2)
    dmean = stacked(n, seed=3, scale=0.5)
    hist = hieavg.History(prev_w=prev, delta_mean=dmean,
                          n_obs=jnp.full((n,), 1.0),
                          miss_count=jnp.zeros((n,)))
    w = stacked(n, seed=4)
    new = hieavg.update_history(hist, w, jnp.array([True, False]))
    for k in prev:
        np.testing.assert_allclose(np.asarray(new.prev_w[k][1]),
                                   np.asarray(prev[k][1] + dmean[k][1]),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(new.prev_w[k][0]),
                                   np.asarray(w[k][0]), rtol=1e-6)


def test_delta_mean_is_running_mean():
    n = 1
    w0 = {"p": jnp.zeros((n, 3))}
    hist = hieavg.init_history(w0)
    for t, val in enumerate((1.0, 3.0), start=1):
        wt = {"p": jnp.full((n, 3), val)}
        hist = hieavg.update_history(hist, wt, jnp.ones(n, bool))
    # deltas: 1-0=1, 3-1=2 -> mean 1.5
    np.testing.assert_allclose(np.asarray(hist.delta_mean["p"]), 1.5,
                               rtol=1e-6)
    assert float(hist.n_obs[0]) == 2.0


def test_normalized_mode_is_affine():
    """Normalized HieAvg keeps the aggregate an affine combination: with
    identical participant weights the aggregate equals that weight."""
    n = 4
    w = {"p": jnp.ones((n, 7)) * 5.0}
    hist = hieavg.History(prev_w=w, delta_mean={"p": jnp.zeros((n, 7))},
                          n_obs=jnp.full((n,), 2.0),
                          miss_count=jnp.zeros((n,)))
    mask = jnp.array([True, False, True, False])
    agg, _ = hieavg.edge_aggregate(w, mask, hist, normalize=True)
    np.testing.assert_allclose(np.asarray(agg["p"]), 5.0, rtol=1e-5)


def test_faithful_mode_shrinks_with_stragglers():
    """The paper's literal eq. (4) divides by J: straggler decay shrinks the
    aggregate norm — the failure mode EXPERIMENTS.md §Perf ablates."""
    n = 4
    w = {"p": jnp.ones((n, 7))}
    hist = hieavg.History(prev_w=w, delta_mean={"p": jnp.zeros((n, 7))},
                          n_obs=jnp.full((n,), 2.0),
                          miss_count=jnp.full((n,), 10.0))  # long-missing
    mask = jnp.array([True, False, True, False])
    agg, _ = hieavg.edge_aggregate(w, mask, hist, normalize=False)
    assert float(jnp.mean(agg["p"])) < 0.8  # < affine value 1.0


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 8), miss=st.integers(0, 5))
def test_property_gamma_bounds(n, miss):
    """0 < γ ≤ γ0 < 1 for any miss count — estimates are always shrunk."""
    w = {"p": jnp.ones((n, 4))}
    hist = hieavg.History(prev_w=w, delta_mean={"p": jnp.zeros((n, 4))},
                          n_obs=jnp.full((n,), 1.0),
                          miss_count=jnp.full((n,), float(miss)))
    mask = jnp.zeros(n, bool).at[0].set(True)
    agg, _ = hieavg.edge_aggregate(w, mask, hist, gamma0=0.9, lam=0.9)
    # aggregate = (1 + (n-1)γ)/n with w=est=1
    gamma = (float(jnp.mean(agg["p"])) * n - 1.0) / (n - 1)
    assert 0.0 < gamma <= 0.9 + 1e-6


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 6), seed=st.integers(0, 100))
def test_property_masked_equals_subset_mean_tfedavg_limit(n, seed):
    """As γ→0 (λ→0 with k'≥1) normalized HieAvg converges to T_FedAvg."""
    from repro.core.baselines import t_fedavg
    w = stacked(n, seed=seed)
    hist = hieavg.History(
        prev_w=stacked(n, seed=seed + 1),
        delta_mean={k: jnp.zeros_like(v) for k, v in
                    stacked(n, seed=1).items()},
        n_obs=jnp.full((n,), 2.0), miss_count=jnp.full((n,), 40.0))
    mask = jnp.ones(n, bool).at[0].set(False)
    agg, _ = hieavg.edge_aggregate(w, mask, hist, gamma0=0.9, lam=1e-3,
                                   normalize=True)
    ref = t_fedavg(w, mask)
    for k in w:
        np.testing.assert_allclose(np.asarray(agg[k]), np.asarray(ref[k]),
                                   atol=1e-4)
