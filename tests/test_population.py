"""Population-scale cohort sampling + delayed-gradient aggregation.

The load-bearing invariant is **cohort-gather parity**: per-round
randomness is keyed by device SLOT and the occupant's profile is gathered
into the slot, so running a gathered cohort out of a large population is
bitwise-identical to materializing the sampled rows as a small
fixed-membership population.  That is what licenses the O(cohort) scaling
claim (BENCH_population.json): the big-population run *is* the small run,
just addressed by index.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bhfl_cnn import REDUCED
from repro.core import baselines
from repro.fl import BHFLSimulator, run_sweep
from repro.fl.population import (DevicePopulation, PopulationSpec,
                                 as_population)

TINY = dataclasses.replace(REDUCED, t_global_rounds=4, n_edges=3,
                           j_per_edge=3, image_hw=8)
KW = dict(n_train=300, n_test=100, steps_per_epoch=2)
POP = 200          # device population; cohort is N x j_cohort = 3 x 3 = 9


def _pop_sim(agg="hieavg", population=POP, j_cohort=3, strag="temporary",
             **kw):
    return BHFLSimulator(TINY, agg, strag, strag, population=population,
                         j_cohort=j_cohort, **KW, **kw)


# ------------------------------------------------------------ store basics
def test_store_profiles_shapes_and_ranges():
    pop = DevicePopulation(PopulationSpec(size=500, j_cohort=3),
                           n_classes=10, seed=0)
    assert pop.classes.shape == (500, 1)
    assert pop.classes.min() >= 0 and pop.classes.max() < 10
    assert pop.miss_prob.shape == (500,)
    assert np.all((pop.miss_prob >= 0) & (pop.miss_prob <= 1))
    # heterogeneous fleet around the spec mean
    assert abs(pop.miss_prob.mean() - 0.2) < 0.05
    assert pop.miss_prob.std() > 0.01
    assert abs(pop.time_scale.mean() - 1.0) < 0.05   # E[time_scale] = 1


def test_cohort_ids_policies():
    pop = DevicePopulation(PopulationSpec(size=100, j_cohort=4,
                                          resample="round"),
                           n_classes=10, seed=0)
    ids = pop.cohort_ids(6, 2, seed=3)
    assert ids.shape == (6, 2, 4)
    assert ids.min() >= 0 and ids.max() < 100
    assert not np.array_equal(ids[0], ids[1])        # fresh per round

    static = DevicePopulation(PopulationSpec(size=100, j_cohort=4,
                                             resample="static"),
                              n_classes=10, seed=0)
    sids = static.cohort_ids(6, 2, seed=3)
    assert np.array_equal(sids[0], sids[-1])         # one draw, kept

    full = DevicePopulation(PopulationSpec(size=8, j_cohort=4,
                                           resample="full"),
                            n_classes=10, seed=0)
    fids = full.cohort_ids(6, 2, seed=3)
    np.testing.assert_array_equal(fids[0].ravel(), np.arange(8))
    with pytest.raises(ValueError, match="population == N"):
        full.cohort_ids(6, 3, seed=3)                # 8 != 3*4


def test_as_population_coercions():
    with pytest.raises(ValueError, match="j_cohort"):
        as_population(100, None, n_classes=10, max_classes=1, seed=0)
    pop = as_population(100, 4, n_classes=10, max_classes=1, seed=0)
    assert pop.size == 100 and pop.spec.j_cohort == 4
    with pytest.raises(ValueError, match="conflicts"):
        as_population(pop, 5, n_classes=10, max_classes=1, seed=0)


def test_simulator_rejects_j_per_edge_with_population():
    with pytest.raises(ValueError, match="j_cohort"):
        BHFLSimulator(TINY, "hieavg", "temporary", "temporary",
                      population=POP, j_cohort=3, j_per_edge=[2, 3, 4],
                      **KW)


def test_run_legacy_refuses_population_mode():
    with pytest.raises(ValueError, match="engine path only"):
        _pop_sim().run_legacy()


# --------------------------------------------------------- gather parity
def test_cohort_gather_parity_bitwise():
    """A gathered cohort out of a 200-device population == the materialized
    subset run as a fixed-membership ("full") population, BITWISE."""
    spec = PopulationSpec(size=POP, j_cohort=3, resample="static")
    big = _pop_sim(population=spec)
    ids = big.cohort_ids[0]                      # static: every round equal
    small = _pop_sim(population=big.pop.subset(ids))
    a, b = big.run(), small.run()
    np.testing.assert_array_equal(a.accuracy, b.accuracy)
    np.testing.assert_array_equal(a.loss, b.loss)
    np.testing.assert_array_equal(a.sim_clock, b.sim_clock)


def test_population_run_repeatable():
    r1, r2 = _pop_sim().run(), _pop_sim().run()
    np.testing.assert_array_equal(r1.accuracy, r2.accuracy)
    np.testing.assert_array_equal(r1.loss, r2.loss)


@pytest.mark.parametrize("agg", ["hieavg", "delayed_grad"])
def test_population_run_is_finite(agg):
    r = _pop_sim(agg)
    out = r.run()
    assert np.all(np.isfinite(out.accuracy))
    assert np.all(np.isfinite(out.loss))
    assert np.all(np.diff(out.sim_clock) > 0)    # clock strictly advances


def test_population_scales_only_store():
    """Growing the population 50x leaves every engine-side shape unchanged
    (the O(cohort) claim at the shape level)."""
    small, big = _pop_sim(population=100), _pop_sim(population=5000)
    assert small.D == big.D == 9
    assert small.cohort_ids.shape == big.cohort_ids.shape
    assert [m.shape for m in small.dev_masks] == \
           [m.shape for m in big.dev_masks]


# --------------------------------------------- delayed-gradient semantics
def test_delayed_grad_staleness_pins():
    """Unit pins for core.baselines.delayed_grad: a missing slot submits
    its pending weights discounted by beta**k', and ages out past delta."""
    w = {"a": jnp.array([[1.0], [3.0]])}
    pend = {"a": jnp.array([[10.0], [20.0]])}
    mask = jnp.array([1.0, 0.0])
    age = jnp.zeros(2)

    agg, new_pend, new_age = baselines.delayed_grad(w, mask, pend, age,
                                                    0.5, 1.0)
    # coef = [1, 0.5 * (k'=1 <= delta)] -> (1*1 + 0.5*20) / 1.5
    np.testing.assert_allclose(np.asarray(agg["a"]), [11.0 / 1.5])
    np.testing.assert_array_equal(np.asarray(new_pend["a"]),
                                  np.asarray(w["a"]))
    np.testing.assert_array_equal(np.asarray(new_age), [0.0, 1.0])

    # second consecutive miss: k' = 2 > delta -> the slot drops entirely
    agg2, _, age2 = baselines.delayed_grad(w, mask, pend, new_age, 0.5, 1.0)
    np.testing.assert_allclose(np.asarray(agg2["a"]), [1.0])
    np.testing.assert_array_equal(np.asarray(age2), [0.0, 2.0])

    # all present: plain weighted mean, ages reset
    agg3, _, age3 = baselines.delayed_grad(w, jnp.ones(2), pend, new_age,
                                           0.5, 1.0)
    np.testing.assert_allclose(np.asarray(agg3["a"]), [2.0])
    np.testing.assert_array_equal(np.asarray(age3), [0.0, 0.0])


def test_delayed_grad_beta_zero_matches_masked_mean():
    """beta = 0 silences stale submissions: identical to masking."""
    w = {"a": jnp.array([[2.0], [6.0], [4.0]])}
    pend = {"a": jnp.array([[9.0], [9.0], [9.0]])}
    mask = jnp.array([1.0, 0.0, 1.0])
    agg, _, _ = baselines.delayed_grad(w, mask, pend, jnp.zeros(3), 0.0, 1.0)
    np.testing.assert_allclose(np.asarray(agg["a"]), [3.0])


# --------------------------------------------- mixed-aggregation sweeps
def test_mixed_aggregation_sweep_matches_single_runs():
    """HieAvg-vs-delayed-gradient as ONE batched traced-switched call,
    per-point equal to standalone engine runs (acceptance criterion)."""
    ovs = [{"aggregation": "hieavg"},
           {"aggregation": "delayed_grad"},
           {"aggregation": "delayed_grad", "staleness_discount": 0.5},
           {"aggregation": "fedavg"}]
    sw = run_sweep(TINY, seeds=(0,), overrides=ovs, **KW)
    for p, ov in enumerate(ovs):
        setting = dataclasses.replace(
            TINY, **{k: v for k, v in ov.items() if k != "aggregation"})
        r = BHFLSimulator(setting, ov["aggregation"], "temporary",
                          "temporary", **KW).run()
        np.testing.assert_allclose(sw.accuracy[p], r.accuracy, atol=1e-6)
        np.testing.assert_allclose(sw.loss[p], r.loss, rtol=1e-5, atol=1e-6)


def test_population_sweep_matches_single_runs():
    """Population mode through the sweep fabric: the O(P) store is built
    once and shared by every grid point; each point still matches its
    standalone engine run."""
    pop = DevicePopulation(PopulationSpec(size=POP, j_cohort=3),
                           n_classes=TINY.n_classes, seed=0)
    ovs = [{"aggregation": "hieavg"}, {"aggregation": "delayed_grad"}]
    sw = run_sweep(TINY, seeds=(0,), overrides=ovs, population=pop, **KW)
    for p, ov in enumerate(ovs):
        r = _pop_sim(ov["aggregation"], population=pop, j_cohort=None).run()
        np.testing.assert_allclose(sw.accuracy[p], r.accuracy, atol=1e-6)


def test_mixed_sweep_rejects_unswitchable():
    with pytest.raises(ValueError, match="traced-switched"):
        run_sweep(TINY, seeds=(0,),
                  overrides=[{"aggregation": "hieavg"},
                             {"aggregation": "t_fedavg"}], **KW)


def test_sweep_rejects_unknown_aggregation():
    with pytest.raises(ValueError, match="unknown aggregation"):
        run_sweep(TINY, seeds=(0,),
                  overrides=[{"aggregation": "median"}], **KW)


def test_single_aggregation_override_keeps_static_dispatch():
    from repro.fl.sweep import plan_sweep
    plan = plan_sweep(TINY, seeds=(0,),
                      overrides=[{"aggregation": "delayed_grad"},
                                 {"aggregation": "delayed_grad",
                                  "staleness_discount": 0.5}], **KW)
    assert plan.aggregator == "delayed_grad"
