"""Baselines, straggler schedules, Raft blockchain, latency optimization."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (BoundParams, LatencyParams, RaftChain, baselines,
                        edge_window, omega_bound, optimize_k, straggler,
                        total_latency)


# ------------------------------------------------------------- baselines
def test_t_fedavg_drops_stragglers():
    w = {"p": jnp.stack([jnp.ones(3), 10 * jnp.ones(3), 2 * jnp.ones(3)])}
    agg = baselines.t_fedavg(w, jnp.array([True, False, True]))
    np.testing.assert_allclose(np.asarray(agg["p"]), 1.5)


def test_d_fedavg_reuses_last_weights():
    w1 = {"p": jnp.stack([jnp.ones(2), 4 * jnp.ones(2)])}
    last = {"p": jnp.zeros((2, 2))}
    agg1, last = baselines.d_fedavg(w1, jnp.array([True, True]), last)
    np.testing.assert_allclose(np.asarray(agg1["p"]), 2.5)
    w2 = {"p": jnp.stack([2 * jnp.ones(2), 99 * jnp.ones(2)])}
    agg2, last = baselines.d_fedavg(w2, jnp.array([True, False]), last)
    np.testing.assert_allclose(np.asarray(agg2["p"]), 3.0)  # (2 + 4)/2
    np.testing.assert_allclose(np.asarray(last["p"][1]), 4.0)


def test_fedavg_weighted():
    w = {"p": jnp.stack([jnp.ones(2), 4 * jnp.ones(2)])}
    agg = baselines.fedavg(w, jnp.array([3.0, 1.0]))
    np.testing.assert_allclose(np.asarray(agg["p"]), 1.75)


# ------------------------------------------------------------ stragglers
def test_permanent_schedule():
    m = straggler.permanent(10, 6, 2, stop_round=4, seed=0)
    assert m[:4].all()
    assert (~m[4:]).sum() == 2 * 6
    gone = np.flatnonzero(~m[5])
    assert len(gone) == 2


def test_temporary_returns_next_round():
    m = straggler.temporary(50, 5, 2, miss_prob=0.7, seed=1)
    miss_r, miss_i = np.nonzero(~m)
    for r, i in zip(miss_r, miss_i):
        if r + 1 < 50:
            assert m[r + 1, i], "temporary straggler must return next round"
    assert m[:2].all(), "cold boot rounds are never missed"


@settings(max_examples=10, deadline=None)
@given(frac=st.sampled_from([0.0, 0.2, 0.4]), n=st.integers(4, 10))
def test_from_fraction_counts(frac, n):
    m = straggler.from_fraction(30, n, frac, kind="permanent", stop_round=3)
    assert (~m[10]).sum() == int(round(frac * n))


# ------------------------------------------------------------ blockchain
def test_raft_election_and_commit():
    chain = RaftChain(5, seed=0)
    leader, t_elect = chain.elect_leader()
    assert 0 <= leader < 5 and t_elect > 0
    blk, t_commit = chain.commit_block("edges", "global")
    assert blk.index == 1 and blk.leader == leader
    assert chain.validate()


def test_raft_leader_failover():
    chain = RaftChain(5, seed=0)
    leader, _ = chain.elect_leader()
    chain.fail_node(leader)
    blk, _ = chain.commit_block("e", "g")   # triggers re-election
    assert blk.leader != leader
    assert chain.validate()


def test_raft_no_majority_raises():
    chain = RaftChain(3, seed=0)
    chain.elect_leader()
    chain.fail_node(0)
    chain.fail_node(1)
    with pytest.raises(RuntimeError):
        chain.commit_block("e", "g")


def test_chain_tamper_detection():
    chain = RaftChain(3, seed=0)
    chain.elect_leader()
    chain.commit_block("e1", "g1")
    chain.commit_block("e2", "g2")
    chain.blocks[1].payload_hash = "tampered"
    assert not chain.validate()


def test_consensus_latency_positive():
    chain = RaftChain(5)
    assert 0 < chain.consensus_latency() < 1.0


# --------------------------------------------------------------- latency
def test_total_latency_linear_in_k():
    p = LatencyParams()
    l1, l2 = total_latency(1, p), total_latency(2, p)
    l3 = total_latency(3, p)
    assert abs((l3 - l2) - (l2 - l1)) < 1e-9
    assert l2 > l1


def test_omega_decreases_in_k():
    """Corollary 1: more edge rounds -> better bound."""
    bp = BoundParams()
    oms = [omega_bound(k, bp) for k in range(1, 30)]
    finite = [o for o in oms if np.isfinite(o)]
    assert len(finite) > 5
    assert all(a >= b - 1e-9 for a, b in zip(finite, finite[1:]))


def test_omega_increases_with_stragglers():
    """Corollary 2: more stragglers -> worse bound."""
    lo = omega_bound(8, BoundParams(s_frac=0.1))
    hi = omega_bound(8, BoundParams(s_frac=0.5))
    assert hi > lo


def test_optimize_k_respects_constraints():
    bp = BoundParams()
    p = LatencyParams()
    res = optimize_k(p, lambda k: omega_bound(k, bp), omega_bar=25.0,
                     consensus_latency=0.5)
    assert res is not None
    k = res.k_star
    assert omega_bound(k, bp) <= 25.0
    assert 0.5 <= edge_window(k, p)
    # K* is the cheapest feasible K
    for kk in range(1, k):
        feasible = (omega_bound(kk, bp) <= 25.0
                    and 0.5 <= edge_window(kk, p))
        assert not feasible or total_latency(kk, p) >= res.latency


def test_optimize_k_infeasible_returns_none():
    bp = BoundParams()
    p = LatencyParams()
    res = optimize_k(p, lambda k: omega_bound(k, bp), omega_bar=1e-9,
                     consensus_latency=0.01, k_max=8)
    assert res is None


def test_k_star_grows_with_consensus_latency():
    """Fig. 7b: longer consensus -> larger K* (C2 needs a wider window)."""
    bp = BoundParams()
    p = LatencyParams()
    ks = []
    for lbc in (0.5, 3.0, 8.0):
        res = optimize_k(p, lambda k: omega_bound(k, bp), omega_bar=25.0,
                         consensus_latency=lbc)
        ks.append(res.k_star if res else np.inf)
    assert ks == sorted(ks)
