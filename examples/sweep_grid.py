"""Multi-seed / multi-fraction grids as ONE batched engine call.

Fig. 3-style sweeps used to loop the simulator point by point; the jitted
engine's ``run_sweep`` stacks every grid point's precomputed inputs
(schedules, batch indices, decay factors) and vmaps the whole grid through
one compiled program — no per-point dispatch, no re-trace.

  PYTHONPATH=src python examples/sweep_grid.py
"""
import dataclasses

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import run_sweep

setting = dataclasses.replace(REDUCED, t_global_rounds=10)

grid = run_sweep(
    setting,
    seeds=(0, 1),
    overrides=[{"straggler_frac": 0.2}, {"straggler_frac": 0.4}],
    normalize=True,
    n_train=1500, n_test=300, steps_per_epoch=4,
)

print("point (overrides, seed)      final_acc  best_acc")
for p, (ov, seed) in enumerate(grid.points):
    acc = grid.accuracy[p]
    print(f"{str(ov):28s} s={seed}  {acc[-1]:.4f}     {acc.max():.4f}")
print(f"\n{len(grid.points)} runs x {setting.t_global_rounds} rounds "
      f"in one vmapped call; {int(grid.blocks.sum())} blocks committed.")
