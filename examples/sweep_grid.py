"""Multi-seed / multi-fraction grids as ONE batched engine call.

Fig. 3-style sweeps used to loop the simulator point by point; the sweep
fabric (``repro.fl.sweep``) plans every grid point's precomputed inputs
(schedules, batch indices, decay factors) into one stacked array pytree and
runs the whole grid through one compiled program — sharded across the
device mesh when the point count divides it, plain ``vmap`` otherwise.
Shape-preserving grids like this one need no padding; see
``examples/sweep_topology.py`` for grids that change the topology itself.

  PYTHONPATH=src python examples/sweep_grid.py
"""
import dataclasses

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import run_sweep

setting = dataclasses.replace(REDUCED, t_global_rounds=10)

grid = run_sweep(
    setting,
    seeds=(0, 1),
    overrides=[{"straggler_frac": 0.2}, {"straggler_frac": 0.4}],
    normalize=True,
    n_train=1500, n_test=300, steps_per_epoch=4,
)

print("point (overrides, seed)      final_acc  best_acc")
for p, (ov, seed) in enumerate(grid.points):
    acc = grid.accuracy[p]
    print(f"{str(ov):28s} s={seed}  {acc[-1]:.4f}     {acc.max():.4f}")
print(f"\n{len(grid.points)} runs x {setting.t_global_rounds} rounds "
      f"in one compiled call; {int(grid.blocks.sum())} blocks committed.")
