"""Latency optimization walkthrough (paper Sec. 5 / Fig. 7).

Shows the two K* selectors of the latency fabric side by side:

  * theoretical — ``optimize_k`` enumerates the dense K axis under the
    Theorem-2 convergence bound (C1) and the consensus-window constraint
    (C2), with the consensus latency from the closed-form Raft model
    (``expected_consensus_latency``, pinned against the discrete-event
    ``RaftChain``);
  * empirical — a bucketed padded sweep over the K grid runs real training on
    the batched engine, and ``SweepResult.k_star_empirical`` picks the K
    whose *measured* convergence reaches a target accuracy in the least
    simulated time.

then prints the full feasibility table for one setting using the
vectorized dense-K model (``total_latency_k``/``edge_window_k``/
``omega_bound_k`` + ``optimize_k_masked``).

  PYTHONPATH=src python examples/latency_optimization.py
"""
import dataclasses

import numpy as np

from repro.configs.bhfl_cnn import REDUCED
from repro.core import (BoundParams, LatencyParams, RaftParams,
                        edge_window_k, expected_consensus_latency,
                        omega_bound, omega_bound_k, optimize_k,
                        optimize_k_masked, total_latency_k)
from repro.fl import run_sweep

bp = BoundParams()
lp = LatencyParams()          # paper's measured Raspberry Pi / EC2 numbers

# 1) theoretical K* vs consensus latency (constraint C2) -----------------
# full per-round consensus (election + commit) — the same L_bc the engine
# clock charges; pass include_election=False for the paper's
# election-amortized steady state instead
print("consensus_latency -> K*  (total latency)  [closed-form Raft model]")
for link in (0.05, 0.2, 0.5, 1.0, 2.0):
    lbc = expected_consensus_latency(RaftParams(link_latency=link), lp.N)
    res = optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=25.0,
                     consensus_latency=lbc)
    if res:
        print(f"  L_bc={lbc:5.2f}s -> K*={res.k_star}  "
              f"({res.latency:8.1f}s)")
    else:
        print(f"  L_bc={lbc:5.2f}s -> infeasible")

# 2) theoretical vs empirical K*: a bucketed sweep over the K grid ------
K_GRID = (1, 2, 4)
setting = dataclasses.replace(REDUCED, t_global_rounds=10)
sw = run_sweep(setting, overrides=[{"k_edge_rounds": k} for k in K_GRID],
               n_train=1500, n_test=300, steps_per_epoch=2, normalize=True)
target = 0.6 * float(sw.accuracy.max())
best, times = sw.k_star_empirical(target)
# full election + commit: the engine's clock charges the whole per-round
# consensus draw, so the theoretical solve must see the same L_bc
lbc = expected_consensus_latency(RaftParams(link_latency=setting.link_latency),
                                 setting.n_edges)
res = optimize_k(LatencyParams(T=10), lambda k: omega_bound(k, bp),
                 omega_bar=25.0, consensus_latency=lbc)
print(f"\ntheoretical vs empirical K* (target acc {target:.2f}):")
print("  K   time_to_target(s)   final_acc")
for p, k in enumerate(K_GRID):
    t = f"{times[p]:.1f}" if np.isfinite(times[p]) else "never"
    clock, acc = sw.latency_trajectory(p)
    print(f"  {k}   {t:>12}         {acc[-1]:.3f}")
print(f"  -> theoretical K* = {res.k_star} (bound-driven), "
      f"empirical K* = {K_GRID[best]} (measured convergence + clock)")

# 3) feasibility table on the vectorized dense-K model -------------------
print("\nfeasibility table (L_bc = 0.45s), dense-K masked argmin:")
lat = total_latency_k(lp, 10)
win = edge_window_k(lp, 10)
om = omega_bound_k(bp, 10)
k_star, k_lat, feas = optimize_k_masked(lat, om, win, 25.0, 0.45)
print("  K   L(K)       edge_window  omega(K)   feasible")
for i in range(10):
    print(f"  {i + 1:2d}  {float(lat[i]):9.1f}  {float(win[i]):6.2f}s"
          f"      {float(om[i]):8.3f}   {bool(feas[i])}")
print(f"\nK* = {int(k_star)}")
