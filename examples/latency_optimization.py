"""Latency optimization walkthrough (paper Sec. 5 / Fig. 7).

Sweeps blockchain consensus latency and shows how the optimal number of
edge-aggregation rounds K* responds (constraint C2: consensus must hide
inside the K-round edge window), then prints the full feasibility table
for one setting.

  PYTHONPATH=src python examples/latency_optimization.py
"""
import numpy as np

from repro.core import (BoundParams, LatencyParams, RaftChain, RaftParams,
                        edge_window, omega_bound, optimize_k, total_latency)

bp = BoundParams()
lp = LatencyParams()          # paper's measured Raspberry Pi / EC2 numbers

print("consensus_latency -> K*  (total latency)")
for link in (0.05, 0.2, 0.5, 1.0, 2.0):
    chain = RaftChain(lp.N, RaftParams(link_latency=link))
    lbc = chain.consensus_latency()
    res = optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=25.0,
                     consensus_latency=lbc)
    if res:
        print(f"  L_bc={lbc:5.2f}s -> K*={res.k_star}  "
              f"({res.latency:8.1f}s)")
    else:
        print(f"  L_bc={lbc:5.2f}s -> infeasible")

print("\nfeasibility table (L_bc = 0.45s):")
print("  K   L(K)       edge_window  omega(K)   feasible")
res = optimize_k(lp, lambda k: omega_bound(k, bp), omega_bar=25.0,
                 consensus_latency=0.45, k_max=10)
for k in range(1, 11):
    om = omega_bound(k, bp)
    print(f"  {k:2d}  {total_latency(k, lp):9.1f}  {edge_window(k, lp):6.2f}s"
          f"      {om:8.3f}   {bool(res.feasible[k - 1])}")
print(f"\nK* = {res.k_star}")
