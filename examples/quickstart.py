"""Quickstart: the paper's BHFL system in ~40 lines.

Five edge servers × five devices train the paper's CNN on non-IID data
with 20% temporary stragglers in both layers; HieAvg handles the missing
submissions; a Raft consortium blockchain of the edge servers commits one
block per global round.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.configs.bhfl_cnn import REDUCED
from repro.core import BoundParams, LatencyParams, omega_bound, optimize_k
from repro.fl import BHFLSimulator

# 1) train BHFL with HieAvg under stragglers -----------------------------
setting = dataclasses.replace(REDUCED, t_global_rounds=15)
sim = BHFLSimulator(setting, aggregator="hieavg",
                    device_stragglers="temporary",
                    edge_stragglers="temporary",
                    n_train=2000, n_test=400, steps_per_epoch=8,
                    normalize=True)
result = sim.run(progress=True)
print(f"\nfinal accuracy {result.accuracy[-1]:.3f} "
      f"in {result.sim_clock[-1]:.0f} simulated seconds "
      f"({result.blocks} blocks committed, "
      f"chain_valid={result.chain_valid})")

# 2) latency optimization: pick K* under the convergence + consensus
#    constraints (Sec. 5.2) ----------------------------------------------
chain_latency = sim.chain.consensus_latency()
res = optimize_k(LatencyParams(), lambda k: omega_bound(k, BoundParams()),
                 omega_bar=25.0, consensus_latency=chain_latency)
print(f"optimal edge rounds K* = {res.k_star} "
      f"(total latency {res.latency:.0f}s, "
      f"consensus hidden in a {chain_latency:.2f}s window)")
