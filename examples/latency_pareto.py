"""Accuracy-vs-latency Pareto front: consensus delay × K, one bucketed sweep.

The paper's central tension (Sec. 5): more edge rounds K converge faster
per global round but stretch the wall clock, while the blockchain's
consensus latency hides inside the K-round edge window only when the
window is long enough (constraint C2).  The latency fabric lets us *map*
that tradeoff empirically — a consensus-multiplier × K grid runs as ONE
compiled sweep, every point carries a simulated-clock trajectory, and the
accuracy-per-second Pareto front falls out.

  PYTHONPATH=src python examples/latency_pareto.py
"""
import dataclasses
import itertools

import numpy as np

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import run_sweep

CONS_MULTS = (1.0, 10.0, 40.0)
K_GRID = (1, 2, 4)

setting = dataclasses.replace(REDUCED, t_global_rounds=10)
overrides = [{"consensus_mult": m, "k_edge_rounds": k}
             for m, k in itertools.product(CONS_MULTS, K_GRID)]
sw = run_sweep(setting, overrides=overrides,
               n_train=1500, n_test=300, steps_per_epoch=2, normalize=True)

# every point: (simulated seconds to finish, best accuracy reached)
cands = []
for p, (ov, _seed) in enumerate(sw.points):
    clock, acc = sw.latency_trajectory(p)
    cands.append((float(clock[-1]), float(acc.max()), ov))

print("consensus_mult  K   sim_seconds  best_acc  acc_per_minute")
for secs, acc, ov in cands:
    print(f"{ov['consensus_mult']:14.0f}  {ov['k_edge_rounds']}  "
          f"{secs:11.1f}  {acc:8.3f}  {60.0 * acc / secs:14.3f}")

# Pareto front: no other point is both faster and more accurate
front = [(s, a, ov) for s, a, ov in cands
         if not any(s2 < s and a2 >= a or (s2 <= s and a2 > a)
                    for s2, a2, _ in cands)]
front.sort(key=lambda c: (c[0], c[1]))
print("\nPareto front (faster -> more accurate):")
for secs, acc, ov in front:
    print(f"  mult={ov['consensus_mult']:.0f} K={ov['k_edge_rounds']}: "
          f"{acc:.3f} acc in {secs:.1f}s")
best = max(cands, key=lambda c: c[1] / c[0])
print(f"\nbest accuracy-per-second: mult={best[2]['consensus_mult']:.0f} "
      f"K={best[2]['k_edge_rounds']} "
      f"({len(sw.points)}-point grid, one bucketed sweep)")
