"""Accuracy-vs-latency Pareto front: consensus protocol × delay × K, one
bucketed sweep.

The paper's central tension (Sec. 5): more edge rounds K converge faster
per global round but stretch the wall clock, while the blockchain's
consensus latency hides inside the K-round edge window only when the
window is long enough (constraint C2).  The latency fabric lets us *map*
that tradeoff empirically — a consensus-zoo × multiplier × K grid runs as
ONE compiled sweep (the protocol is a data-batched field, like the
multiplier), every point carries simulated-clock AND consensus-energy
trajectories, and the accuracy-per-second Pareto front falls out with the
protocol's Joule bill beside it.

  PYTHONPATH=src python examples/latency_pareto.py
"""
import dataclasses
import itertools

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import run_sweep

CONSENSUS = ("raft", "pofel", "sharded")
CONS_MULTS = (1.0, 40.0)
K_GRID = (1, 2, 4)

setting = dataclasses.replace(REDUCED, t_global_rounds=10)
overrides = [{"consensus": c, "consensus_mult": m, "k_edge_rounds": k}
             for c, m, k in itertools.product(CONSENSUS, CONS_MULTS, K_GRID)]
sw = run_sweep(setting, overrides=overrides,
               n_train=1500, n_test=300, steps_per_epoch=2, normalize=True)

# every point: (simulated seconds, best accuracy, consensus Joules)
cands = []
for p, (ov, _seed) in enumerate(sw.points):
    clock, acc = sw.latency_trajectory(p)
    _, energy = sw.energy_trajectory(p)
    cands.append((float(clock[-1]), float(acc.max()), float(energy[-1]), ov))

print("consensus  mult  K   sim_seconds  best_acc  acc_per_minute  energy_J")
for secs, acc, joules, ov in cands:
    print(f"{ov['consensus']:>9}  {ov['consensus_mult']:4.0f}  "
          f"{ov['k_edge_rounds']}  {secs:11.1f}  {acc:8.3f}  "
          f"{60.0 * acc / secs:14.3f}  {joules:8.2f}")

# Pareto front: no other point is both faster and more accurate
front = [(s, a, e, ov) for s, a, e, ov in cands
         if not any(s2 < s and a2 >= a or (s2 <= s and a2 > a)
                    for s2, a2, _, _ in cands)]
front.sort(key=lambda c: (c[0], c[1]))
print("\nPareto front (faster -> more accurate):")
for secs, acc, joules, ov in front:
    print(f"  {ov['consensus']} mult={ov['consensus_mult']:.0f} "
          f"K={ov['k_edge_rounds']}: {acc:.3f} acc in {secs:.1f}s "
          f"({joules:.2f} J consensus)")
best = max(cands, key=lambda c: c[1] / c[0])
frugal = min(cands, key=lambda c: c[2])
print(f"\nbest accuracy-per-second: {best[3]['consensus']} "
      f"mult={best[3]['consensus_mult']:.0f} K={best[3]['k_edge_rounds']}")
print(f"lowest consensus energy:  {frugal[3]['consensus']} "
      f"({frugal[2]:.2f} J over {setting.t_global_rounds} rounds; "
      f"{len(sw.points)}-point grid, one bucketed sweep)")
