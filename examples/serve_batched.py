"""Batched serving example: prefill a prompt batch, decode with the
cached-state path (KV cache / MLA latent / SSM state, per architecture).

  PYTHONPATH=src python examples/serve_batched.py [arch]
"""
import sys

from repro.launch import serve

arch = sys.argv[1] if len(sys.argv) > 1 else "mamba2-130m"
out = serve.run(arch, smoke=True, batch=4, prompt_len=48, gen=24,
                temperature=0.8)
print(f"\n{arch}: generated {out['tokens'].shape[1]} tokens x "
      f"{out['tokens'].shape[0]} sequences")
print("first sequence token ids:", out["tokens"][0][:16].tolist())
