"""Single-point-of-failure drill — the paper's core motivation for BHFL.

A centralized HFL deployment halts if the aggregation server dies.  Here
the Raft leader crashes mid-training: the consortium re-elects among the
surviving edge servers, the failed edge becomes a permanent straggler
(HieAvg estimates its submissions), and training finishes every round
with an intact block chain.

  PYTHONPATH=src python examples/leader_failover.py
"""
import dataclasses

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import BHFLSimulator

setting = dataclasses.replace(REDUCED, t_global_rounds=16)
sim = BHFLSimulator(setting, "hieavg", "temporary", "temporary",
                    normalize=True, fail_leader_at=8,
                    n_train=2000, n_test=400, steps_per_epoch=8)
r = sim.run(progress=True)

print(f"\nleader crashed at round 8 — training continued:")
print(f"  rounds completed : {len(r.accuracy)}/{setting.t_global_rounds}")
print(f"  blocks committed : {r.blocks} (chain valid: {r.chain_valid})")
print(f"  surviving edges  : {int(sim.chain.alive.sum())}/{sim.N} "
      f"(new leader: edge {sim.chain.leader})")
print(f"  final accuracy   : {r.accuracy[-1]:.3f}")
