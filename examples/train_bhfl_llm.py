"""End-to-end driver: hierarchical BHFL training of a transformer LM.

Runs the framework-scale path (Layout A, HieAvg at both layers, Raft
consensus, checkpointing) on a reduced h2o-danube variant — a few hundred
steps of a ~1M-param model on CPU; the identical driver runs the 16x16
production mesh on TPU (drop --smoke).

The driver takes the engine path (``fused=True``, the default): the whole
T×K-round run is ONE ``lax.scan``-compiled program — batches, straggler
masks, and the lr schedule precomputed host-side, the Raft chain replayed
up front with its election+commit latency feeding a simulated clock — the
same orchestration as the CNN engine, so no example drives the legacy
per-round Python loop anymore.

  PYTHONPATH=src python examples/train_bhfl_llm.py
"""
import tempfile

from repro.launch import train

with tempfile.TemporaryDirectory() as ckpt:
    out = train.run("h2o-danube-1.8b", smoke=True, steps=40, k_edge=2,
                    n_clients=4, batch=4, seq=64, straggler_frac=0.25,
                    normalize=True, ckpt_dir=ckpt)
    print(f"\nloss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"over {len(out['losses'])} global rounds "
          f"({out['sim_clock'][-1]:.0f} simulated seconds)")
    print(f"blockchain: {out['blocks']} blocks, valid={out['chain_valid']}")
    assert out["losses"][-1] < out["losses"][0], "training must make progress"
