"""Topology grids — N edges x J devices x K edge rounds — as ONE call.

Before the sweep fabric this was impossible: changing ``n_edges``,
``j_per_edge``, or ``k_edge_rounds`` changes every engine array shape, so
each point forced its own compiled run.  The planner
(``repro.fl.sweep.plan_sweep``) pads every point to the grid maxima —
padded edges/devices carry zero aggregation weight, padded edge rounds
pass the scan carry through — and the stacked grid executes as one
compiled program, sharded over the mesh ``data`` axis when the point count
divides the device count.

  PYTHONPATH=src python examples/sweep_topology.py
"""
import dataclasses
import itertools

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import run_sweep

setting = dataclasses.replace(REDUCED, t_global_rounds=8)

overrides = [
    {"n_edges": n, "j_per_edge": j, "k_edge_rounds": k}
    for n, j, k in itertools.product((2, 4), (2, 4), (1, 2))
]

grid = run_sweep(
    setting,
    overrides=overrides,
    normalize=True,
    n_train=1500, n_test=300, steps_per_epoch=2,
)

print("N  J  K   final_acc  best_acc  latency(s)")
for p, (ov, _seed) in enumerate(grid.points):
    acc, _, _ = grid.trajectory(p)
    print(f"{ov['n_edges']}  {ov['j_per_edge']}  {ov['k_edge_rounds']}   "
          f"{acc[-1]:.4f}     {acc.max():.4f}    "
          f"{grid.sim_latency[p]:8.1f}")
print(f"\n{len(grid.points)}-point N x J x K grid in one compiled call "
      f"(padded to N={max(o['n_edges'] for o in overrides)}, "
      f"J={max(o['j_per_edge'] for o in overrides)}, "
      f"K={max(o['k_edge_rounds'] for o in overrides)}).")
