"""Topology grids — N edges x J devices x K edge rounds — in a few calls.

Before the sweep fabric this was impossible: changing ``n_edges``,
``j_per_edge``, or ``k_edge_rounds`` changes every engine array shape, so
each point forced its own compiled run.  The shape-bucketed planner
(``repro.fl.sweep.plan_sweep``) groups the grid into a handful of
compatible-shape buckets — padded edges/devices carry zero aggregation
weight, padded edge rounds pass the scan carry through — and each bucket
executes as one compiled program, sharded over the mesh ``data`` axis when
its point count divides the device count.  The printed plan shows exactly
what the planner chose: bucket count, per-bucket padded shapes, and the
padded-compute waste vs. both the no-padding ideal and the old
pad-everything-to-the-global-max baseline.

  PYTHONPATH=src python examples/sweep_topology.py
"""
import dataclasses
import itertools

from repro.configs.bhfl_cnn import REDUCED
from repro.fl import plan_sweep, run_plan

setting = dataclasses.replace(REDUCED, t_global_rounds=8)

overrides = [
    {"n_edges": n, "j_per_edge": j, "k_edge_rounds": k}
    for n, j, k in itertools.product((2, 4), (2, 4), (1, 2))
]

plan = plan_sweep(
    setting,
    overrides=overrides,
    normalize=True,
    n_train=1500, n_test=300, steps_per_epoch=2,
)
print(plan.describe())
print()
grid = run_plan(plan)

print("N  J  K   final_acc  best_acc  latency(s)")
for p, (ov, _seed) in enumerate(grid.points):
    acc, _, _ = grid.trajectory(p)
    print(f"{ov['n_edges']}  {ov['j_per_edge']}  {ov['k_edge_rounds']}   "
          f"{acc[-1]:.4f}     {acc.max():.4f}    "
          f"{grid.sim_latency[p]:8.1f}")
print(f"\n{len(grid.points)}-point N x J x K grid in "
      f"{len(plan.buckets)} compiled call(s) "
      f"(padded-compute waste "
      f"{plan.padding_stats()['padded_flop_frac']:.1%}, vs "
      f"{plan.padding_stats()['single_bucket_flop_frac']:.1%} had every "
      f"point been padded to the single grid max).")
