"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) pair.

Proves the distribution config is coherent without TPU hardware: 512
placeholder host devices stand in for 2 pods × 256 chips.  For each pair we
record memory_analysis (fits-or-not), cost_analysis (FLOPs/bytes), and the
collective-op byte census parsed from the compiled HLO — the inputs to the
roofline analysis (EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --out dryrun_results.json
"""
# The first two lines MUST run before any other import so jax sees 512
# devices when it locks the platform on first init.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs import ARCH_IDS, get_config           # noqa: E402
from repro.models import INPUT_SHAPES                    # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.launch.inputs import input_specs, output_shardings  # noqa: E402
from repro.launch.steps import (make_hfl_train_step,     # noqa: E402
                                make_prefill_step, make_serve_step)

_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
          "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
          "f64": 8}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tensor_bytes(sig: str) -> int:
    """Sum byte sizes of every dtype[shape] group in an HLO type signature."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dtype]
    return total


def _split_computations(hlo_text: str) -> dict[str, str]:
    """{computation name: body text} from an HLO module dump."""
    comps: dict[str, str] = {}
    cur, buf = None, []
    for line in hlo_text.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", line)
        if m and not line.startswith(" "):
            cur = m.group(1)
            buf = []
            continue
        if cur is not None:
            if line.startswith("}"):
                comps[cur] = "\n".join(buf)
                cur = None
            else:
                buf.append(line)
    return comps


def _computation_multipliers(comps: dict[str, str]) -> dict[str, float]:
    """Execution-count multiplier per computation.

    XLA dumps each while (scan) body ONCE; its ops execute trip-count
    times.  We extract trip counts from the while condition's comparison
    constant and propagate multipliers along the call graph — so per-layer
    collectives inside the layers scan are weighted by n_layers, nested
    scans (q-chunk loops, chunked recurrences) multiply out.
    """
    entry = None
    for name in comps:
        if name.startswith("main") or entry is None:
            if name.startswith("main"):
                entry = name
    if entry is None:
        entry = next(iter(comps))

    def trip_count(cond_name: str) -> float:
        text = comps.get(cond_name, "")
        consts = [int(x) for x in
                  re.findall(r"constant\((\d+)\)", text)]
        return float(max(consts)) if consts else 1.0

    # call edges: (caller, callee, weight)
    edges: list[tuple[str, str, float]] = []
    for name, text in comps.items():
        for m in re.finditer(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)",
                             text):
            cond, body = m.group(1), m.group(2)
            edges.append((name, body, trip_count(cond)))
        for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", text):
            edges.append((name, m.group(1), 1.0))

    mult = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # propagate (graph is a DAG of computations; a few passes suffice)
    for _ in range(20):
        changed = False
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        for caller, callee, w in edges:
            if callee in new:
                new[callee] += mult.get(caller, 0.0) * w
        for name in comps:
            tgt = max(new[name], 1.0 if name == entry else 0.0)
            if abs(tgt - mult[name]) > 1e-9:
                changed = True
            mult[name] = tgt
        if not changed:
            break
    return mult


def collective_census(hlo_text: str) -> dict:
    """Per-collective byte totals from compiled HLO, weighted by the
    execution count of the enclosing computation (while-aware)."""
    comps = _split_computations(hlo_text)
    mult = _computation_multipliers(comps)
    out = {k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for cname, text in comps.items():
        w = max(mult.get(cname, 1.0), 1.0) if cname in mult else 1.0
        for line in text.splitlines():
            ls = line.strip()
            for kind in _COLLECTIVES:
                m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+" + kind
                             + r"(?:-start)?\(", ls)
                if m:
                    if kind + "-done(" in ls:
                        break
                    out[kind]["count"] += 1
                    out[kind]["bytes"] += _tensor_bytes(m.group(1)) * w
                    break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def applicable(arch: str, shape_name: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "full quadratic attention — 512k decode infeasible " \
                      "by design (DESIGN.md §Arch-applicability)"
    return True, ""


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             extra_metadata: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}

    with mesh:
        specs = input_specs(cfg, shape, mesh)
        if shape.kind == "train":
            # microbatch to an ~8-sequence activation working set
            from repro.launch.inputs import fl_dims
            _, _, b_client = fl_dims(cfg, shape, mesh)
            # FSDP clients (1/pod) re-gather weights per microbatch: use
            # fewer, larger microbatches (measured sweet spot, §Perf G1)
            target = 16 if cfg.clients_per_pod == 1 else 8
            n_micro = max(b_client // target, 1)
            rec["n_micro"] = n_micro
            step = make_hfl_train_step(cfg, mesh=mesh, n_micro=n_micro)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, mesh=mesh)
        else:
            step = make_serve_step(cfg, mesh=mesh)

        out_shd = output_shardings(cfg, shape, mesh)
        # NOTE on donation: donating params/histories (train) and caches
        # (serve) is the right production setting on TPU (in-place state
        # update, saves ~argument_size of HBM), but XLA:CPU ignores
        # donation and its memory_analysis then reports *larger* temp —
        # measured +8 GiB noise at dsv2 train.  We lower without donation
        # so the reported numbers reflect the analyzable graph
        # (EXPERIMENTS.md §Perf, iteration D4).
        t0 = time.time()
        lowered = jax.jit(step, out_shardings=out_shd).lower(**specs)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            rec["memory"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
            rec["bytes_per_device"] = (
                rec["memory"].get("argument_size_in_bytes", 0)
                + rec["memory"].get("temp_size_in_bytes", 0))
        cost = compiled.cost_analysis()
        if cost:
            c = cost if isinstance(cost, dict) else cost[0]
            rec["flops"] = float(c.get("flops", -1.0))
            rec["hlo_bytes"] = float(c.get("bytes accessed", -1.0))
        if extra_metadata:
            rec["collectives"] = collective_census(compiled.as_text())
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    pairs = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(INPUT_SHAPES) if (args.all or not args.shape) \
        else (args.shape,)
    meshes = {"pod": (False,), "multipod": (True,),
              "both": (False, True)}[args.mesh]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    results, failures = [], 0
    for a, s, mp in pairs:
        ok, why = applicable(a, s)
        label = f"{a} x {s} x {'2x16x16' if mp else '16x16'}"
        if not ok:
            print(f"SKIP {label}: {why}")
            results.append({"arch": a, "shape": s,
                            "mesh": "2x16x16" if mp else "16x16",
                            "skipped": why})
            continue
        try:
            rec = run_pair(a, s, mp)
            coll = rec.get("collectives", {})
            print(f"OK   {label}: compile={rec['compile_s']}s "
                  f"flops={rec.get('flops', 0):.3e} "
                  f"coll={coll.get('total_bytes', 0):.3e}B "
                  f"mem/dev={rec.get('bytes_per_device', 0)/2**30:.2f}GiB")
            results.append(rec)
        except Exception as e:  # a failure here is a sharding bug
            failures += 1
            print(f"FAIL {label}: {e}")
            traceback.print_exc()
            results.append({"arch": a, "shape": s,
                            "mesh": "2x16x16" if mp else "16x16",
                            "error": str(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
