"""End-to-end hierarchical BHFL SPMD training driver.

Runs the paper's full workflow at framework scale: K edge rounds per global
round, HieAvg at both layers, Raft consensus latency accounting, straggler
schedules, checkpointing.  On this CPU container use ``--smoke`` (reduced
arch, debug mesh); on a TPU pod the same driver runs the production mesh.

By default the T×K rounds run engine-style (``fused=True``): batches,
masks, and the lr schedule are precomputed host-side, the Raft chain is
replayed up front (its per-round election+commit latency feeds a
simulated clock, like ``repro.fl.engine``), and the whole run is ONE
``lax.scan``-compiled program instead of a Python dispatch per edge
round.  ``fused=False`` keeps the original per-round loop (periodic
mid-run checkpoints; otherwise identical math — the fused path consumes
the batch/chain RNG streams in the same order).

``kernel_mode`` is the kernel-plane knob (``repro.kernels.dispatch``):
``"auto"`` resolves to the Pallas flash-attention kernel on TPU/GPU and
the XLA einsum path on CPU.  This driver refuses ``"interpret"`` — the
Pallas interpreter is a test/validation tool, not a production path.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \\
      --smoke --steps 20 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.core import (LatencyParams, RaftChain, RaftParams, straggler,
                        stream_rng, stream_seed)
from repro.data import lm_tokens
from repro.kernels.dispatch import KERNEL_MODES, resolve_kernel_mode
from repro.launch.inputs import _memory_shape
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.steps import init_fl_histories, make_hfl_train_step
from repro.models import attention, init_from_specs, param_specs
from repro.optim import paper_lr


def run(arch: str, *, smoke: bool = True, steps: int = 20, k_edge: int = 2,
        n_clients: int = 2, batch: int = 4, seq: int = 64,
        straggler_frac: float = 0.2, gamma0: float = 0.9, lam: float = 0.9,
        normalize: bool = True, ckpt_dir: str | None = None,
        seed: int = 0, progress: bool = True, fused: bool = True,
        kernel_mode: str = "auto",
        lat_params: LatencyParams | None = None) -> dict:
    kernel_mode = resolve_kernel_mode(kernel_mode)
    if kernel_mode == "interpret":
        raise ValueError(
            "train.run(kernel_mode='interpret'): the Pallas interpreter is "
            "a test/validation path, not a training backend — use 'auto', "
            "'pallas' (TPU/GPU), or 'xla'")
    cfg = get_smoke(arch) if smoke else get_config(arch)
    mesh = make_debug_mesh() if smoke else make_production_mesh()
    e, c = 1 if smoke else 2, n_clients

    key = jax.random.key(seed)
    base = init_from_specs(param_specs(cfg), key)
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (e, c) + x.shape),
                          base)
    dev_hist, glob_hist = init_fl_histories(params)
    step = make_hfl_train_step(
        cfg, gamma0=gamma0, lam=lam, normalize=normalize,
        mesh=None if smoke else mesh)

    # straggler schedules + Raft chain (the BHFL control plane).  Each
    # consumer gets its own SeedSequence stream (core.rng) — the same
    # registry the CNN simulator uses, so no two schedules ever collide.
    dev_masks = straggler.from_fraction(steps * k_edge + 1, e * c,
                                        straggler_frac,
                                        seed=stream_seed(seed, "dev_masks"))
    edge_masks = straggler.from_fraction(steps + 1, e, straggler_frac,
                                         seed=stream_seed(seed, "edge_masks"))
    lp = lat_params or LatencyParams(T=steps, N=e, J=c)
    chain = RaftChain(max(e, 1), RaftParams(),
                      seed=stream_seed(seed, "chain"))

    data = lm_tokens(e * c * batch * 4, seq + 1, cfg.vocab,
                     seed=stream_seed(seed, "data"))
    ms = _memory_shape(cfg)
    rng = stream_rng(seed, "batches")

    prev_flash = attention.USE_FLASH_KERNEL
    attention.USE_FLASH_KERNEL = kernel_mode == "pallas"
    try:
        return _run_timed(cfg, mesh, step, params, dev_hist, glob_hist,
                          chain, dev_masks, edge_masks, data, ms, rng, lp,
                          steps=steps, k_edge=k_edge, e=e, c=c, batch=batch,
                          seq=seq, progress=progress, fused=fused,
                          ckpt_dir=ckpt_dir)
    finally:
        attention.USE_FLASH_KERNEL = prev_flash


def _run_timed(cfg, mesh, step, params, dev_hist, glob_hist, chain,
               dev_masks, edge_masks, data, ms, rng, lp, *, steps, k_edge,
               e, c, batch, seq, progress, fused, ckpt_dir) -> dict:
    t0 = time.time()
    if fused:
        out = _run_fused(cfg, mesh, step, params, dev_hist, glob_hist,
                         chain, dev_masks, edge_masks, data, ms, rng, lp,
                         steps=steps, k_edge=k_edge, e=e, c=c, batch=batch,
                         seq=seq, progress=progress)
        glob = out.pop("global_model")
        if ckpt_dir:
            save_checkpoint(ckpt_dir, steps, glob,
                            metadata={"round": steps,
                                      "block": len(chain.blocks) - 1})
        return {**out, "wall": time.time() - t0,
                "blocks": len(chain.blocks) - 1,
                "chain_valid": chain.validate()}

    step = jax.jit(step)
    losses = []
    with mesh:
        for t in range(steps):
            chain.elect_leader()
            for k in range(k_edge):
                idx = rng.integers(0, data.shape[0], e * c * batch)
                chunk = data[idx].reshape(e, c, batch, seq + 1)
                b = {"tokens": jnp.asarray(chunk[..., :-1]),
                     "labels": jnp.asarray(chunk[..., 1:])}
                if ms is not None:
                    b["memory"] = jnp.zeros((e, c, batch) + ms,
                                            cfg.jnp_param_dtype)
                dm = jnp.asarray(dev_masks[t * k_edge + k].reshape(e, c))
                em = jnp.asarray(edge_masks[t])
                lr = paper_lr(jnp.asarray(t * k_edge + k, jnp.float32),
                              1e-2, 0.3)
                params, dev_hist, glob_hist, loss = step(
                    params, dev_hist, glob_hist, b, dm, em, lr)
            chain.commit_block(f"edges@{t}", f"global@{t}")
            losses.append(float(loss))
            if progress and (t % 5 == 0 or t == steps - 1):
                print(f"  global round {t:3d}  loss {losses[-1]:.4f}")
            if ckpt_dir and (t + 1) % 10 == 0:
                glob = jax.tree.map(lambda x: np.asarray(x[0, 0]), params)
                save_checkpoint(ckpt_dir, t + 1, glob,
                                metadata={"round": t + 1,
                                          "block": len(chain.blocks) - 1})
    return {"losses": losses, "wall": time.time() - t0,
            "blocks": len(chain.blocks) - 1, "chain_valid": chain.validate()}


def _run_fused(cfg, mesh, step, params, dev_hist, glob_hist, chain,
               dev_masks, edge_masks, data, ms, rng, lp: LatencyParams, *,
               steps: int, k_edge: int, e: int, c: int, batch: int,
               seq: int, progress: bool) -> dict:
    """The engine path: all T×K rounds as ONE ``lax.scan``-compiled program.

    Batches are drawn host-side in the same (t, k) order as the legacy
    loop (same ``rng`` stream → identical indices), the Raft chain is
    replayed up front (same election winners, same block chain), and the
    scan consumes stacked per-round arrays — one compile and one dispatch
    for the whole run, the same orchestration the CNN engine
    (``repro.fl.engine``) uses.

    Latency accounting is expectation-level (this driver has no per-device
    time draws): each global round costs the K-round edge window
    ``k_edge * (2 lm_device + lp_device)``, the edge<->leader hop, and any
    consensus stall ``max(0, L_bc - window)`` with L_bc the replayed
    election+commit elapsed — the same C2 semantics as the CNN engine.
    """
    R = steps * k_edge
    idx = np.stack([rng.integers(0, data.shape[0], e * c * batch)
                    for _ in range(R)])                   # legacy draw order
    chunks = data[idx].reshape(R, e, c, batch, seq + 1)
    tokens = jnp.asarray(chunks[..., :-1])
    labels = jnp.asarray(chunks[..., 1:])
    dms = jnp.asarray(dev_masks[:R].reshape(R, e, c))
    ems = jnp.asarray(edge_masks[np.arange(R) // k_edge])
    lrs = paper_lr(jnp.arange(R, dtype=jnp.float32), 1e-2, 0.3)

    cons = np.zeros(steps)
    for t in range(steps):
        _, t_elect = chain.elect_leader()
        _, t_commit = chain.commit_block(f"edges@{t}", f"global@{t}")
        cons[t] = t_elect + t_commit
    window = k_edge * (2.0 * lp.lm_device + lp.lp_device)
    sim_clock = np.cumsum(window + 2.0 * lp.lm_edge
                          + np.maximum(0.0, cons - window))

    def body(carry, xs):
        params, dev_hist, glob_hist = carry
        tk, lb, dm, em, lr = xs
        b = {"tokens": tk, "labels": lb}
        if ms is not None:
            b["memory"] = jnp.zeros((e, c, batch) + ms, cfg.jnp_param_dtype)
        params, dev_hist, glob_hist, loss = step(
            params, dev_hist, glob_hist, b, dm, em, lr)
        return (params, dev_hist, glob_hist), loss

    @jax.jit
    def fused(carry, xs):
        return jax.lax.scan(body, carry, xs)

    with mesh:
        (params, dev_hist, glob_hist), losses_r = fused(
            (params, dev_hist, glob_hist), (tokens, labels, dms, ems, lrs))
    # the legacy loop reports each global round's LAST edge-round loss
    losses = [float(x) for x in
              np.asarray(losses_r).reshape(steps, k_edge)[:, -1]]
    if progress:
        for t in range(steps):
            if t % 5 == 0 or t == steps - 1:
                print(f"  global round {t:3d}  loss {losses[t]:.4f}  "
                      f"clock {sim_clock[t]:.1f}s")
    return {"losses": losses, "sim_clock": sim_clock,
            "global_model": jax.tree.map(lambda x: np.asarray(x[0, 0]),
                                         params)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--k-edge", type=int, default=2)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--kernel-mode", default="auto",
                    choices=[m for m in KERNEL_MODES if m != "interpret"],
                    help="kernel-plane backend (auto resolves per device; "
                         "'interpret' is test-only and refused here)")
    args = ap.parse_args()
    out = run(args.arch, smoke=args.smoke, steps=args.steps,
              k_edge=args.k_edge, n_clients=args.clients, batch=args.batch,
              seq=args.seq, ckpt_dir=args.ckpt_dir,
              kernel_mode=args.kernel_mode)
    print(f"done: loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}, "
          f"{out['blocks']} blocks, chain_valid={out['chain_valid']}, "
          f"{out['wall']:.1f}s")


if __name__ == "__main__":
    main()
