"""End-to-end hierarchical BHFL SPMD training driver.

Runs the paper's full workflow at framework scale: K edge rounds per global
round, HieAvg at both layers, Raft consensus latency accounting, straggler
schedules, checkpointing.  On this CPU container use ``--smoke`` (reduced
arch, debug mesh); on a TPU pod the same driver runs the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \\
      --smoke --steps 20 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.core import RaftChain, straggler
from repro.data import lm_tokens
from repro.launch.inputs import _memory_shape
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.steps import init_fl_histories, make_hfl_train_step
from repro.models import init_from_specs, param_specs
from repro.optim import paper_lr


def run(arch: str, *, smoke: bool = True, steps: int = 20, k_edge: int = 2,
        n_clients: int = 2, batch: int = 4, seq: int = 64,
        straggler_frac: float = 0.2, gamma0: float = 0.9, lam: float = 0.9,
        normalize: bool = True, ckpt_dir: str | None = None,
        seed: int = 0, progress: bool = True) -> dict:
    cfg = get_smoke(arch) if smoke else get_config(arch)
    mesh = make_debug_mesh() if smoke else make_production_mesh()
    e, c = 1 if smoke else 2, n_clients

    key = jax.random.key(seed)
    base = init_from_specs(param_specs(cfg), key)
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (e, c) + x.shape),
                          base)
    dev_hist, glob_hist = init_fl_histories(params)
    step = jax.jit(make_hfl_train_step(
        cfg, gamma0=gamma0, lam=lam, normalize=normalize,
        mesh=None if smoke else mesh))

    # straggler schedules + Raft chain (the BHFL control plane)
    dev_masks = straggler.from_fraction(steps * k_edge + 1, e * c,
                                        straggler_frac, seed=seed)
    edge_masks = straggler.from_fraction(steps + 1, e, straggler_frac,
                                         seed=seed + 1)
    chain = RaftChain(max(e, 1), seed=seed)

    data = lm_tokens(e * c * batch * 4, seq + 1, cfg.vocab, seed=seed)
    ms = _memory_shape(cfg)
    rng = np.random.default_rng(seed)

    losses, t0 = [], time.time()
    with mesh:
        for t in range(steps):
            chain.elect_leader()
            for k in range(k_edge):
                idx = rng.integers(0, data.shape[0], e * c * batch)
                chunk = data[idx].reshape(e, c, batch, seq + 1)
                b = {"tokens": jnp.asarray(chunk[..., :-1]),
                     "labels": jnp.asarray(chunk[..., 1:])}
                if ms is not None:
                    b["memory"] = jnp.zeros((e, c, batch) + ms,
                                            cfg.jnp_param_dtype)
                dm = jnp.asarray(dev_masks[t * k_edge + k].reshape(e, c))
                em = jnp.asarray(edge_masks[t])
                lr = paper_lr(jnp.asarray(t * k_edge + k, jnp.float32),
                              1e-2, 0.3)
                params, dev_hist, glob_hist, loss = step(
                    params, dev_hist, glob_hist, b, dm, em, lr)
            chain.commit_block(f"edges@{t}", f"global@{t}")
            losses.append(float(loss))
            if progress and (t % 5 == 0 or t == steps - 1):
                print(f"  global round {t:3d}  loss {losses[-1]:.4f}")
            if ckpt_dir and (t + 1) % 10 == 0:
                glob = jax.tree.map(lambda x: np.asarray(x[0, 0]), params)
                save_checkpoint(ckpt_dir, t + 1, glob,
                                metadata={"round": t + 1,
                                          "block": len(chain.blocks) - 1})
    return {"losses": losses, "wall": time.time() - t0,
            "blocks": len(chain.blocks) - 1, "chain_valid": chain.validate()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--k-edge", type=int, default=2)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    out = run(args.arch, smoke=args.smoke, steps=args.steps,
              k_edge=args.k_edge, n_clients=args.clients, batch=args.batch,
              seq=args.seq, ckpt_dir=args.ckpt_dir)
    print(f"done: loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}, "
          f"{out['blocks']} blocks, chain_valid={out['chain_valid']}, "
          f"{out['wall']:.1f}s")


if __name__ == "__main__":
    main()
