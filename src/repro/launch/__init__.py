from .mesh import (make_production_mesh, make_debug_mesh, make_sweep_mesh,
                   mesh_axis_size, PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
from .sharding import resolve_kernel_mode
from .steps import (make_hfl_train_step, make_prefill_step, make_serve_step,
                    make_train_step, init_fl_histories)
from .inputs import input_specs, train_input_specs, serve_input_specs

__all__ = [
    "make_production_mesh", "make_debug_mesh", "make_sweep_mesh",
    "mesh_axis_size", "resolve_kernel_mode",
    "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW",
    "make_hfl_train_step", "make_prefill_step", "make_serve_step",
    "make_train_step", "init_fl_histories",
    "input_specs", "train_input_specs", "serve_input_specs",
]
