"""Batched serving driver: prefill a prompt batch, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \\
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.data import lm_tokens
from repro.launch.inputs import _memory_shape
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import cache_specs, init_from_specs, param_specs


def run(arch: str, *, smoke: bool = True, batch: int = 4,
        prompt_len: int = 32, gen: int = 16, temperature: float = 0.0,
        seed: int = 0, progress: bool = True) -> dict:
    cfg = get_smoke(arch) if smoke else get_config(arch)
    mesh = make_debug_mesh() if smoke else make_production_mesh()
    max_len = prompt_len + gen

    params = init_from_specs(param_specs(cfg), jax.random.key(seed))
    caches = init_from_specs(
        cache_specs(cfg, batch, max_len,
                    dtype=jnp.float32 if smoke else jnp.bfloat16),
        jax.random.key(seed + 1))
    prompts = jnp.asarray(lm_tokens(batch, prompt_len, cfg.vocab, seed=seed))
    ms = _memory_shape(cfg)
    mem = (jnp.zeros((batch,) + ms, cfg.jnp_param_dtype)
           if ms is not None else None)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    with mesh:
        logits, caches = prefill(params, prompts, caches, mem)
        toks = [jnp.argmax(logits, -1).astype(jnp.int32)]
        t_prefill = time.time() - t0
        t0 = time.time()
        for i in range(gen - 1):
            tok = toks[-1][:, None]
            logits, caches = decode(params, tok,
                                    jnp.asarray(prompt_len + i, jnp.int32),
                                    caches, mem)
            if temperature > 0:
                key = jax.random.key(seed + 2 + i)
                nxt = jax.random.categorical(key, logits / temperature, -1)
            else:
                nxt = jnp.argmax(logits, -1)
            toks.append(nxt.astype(jnp.int32))
        out = jnp.stack(toks, axis=1)
        out.block_until_ready()
    t_decode = time.time() - t0
    if progress:
        print(f"  prefill {prompt_len} toks x{batch}: {t_prefill:.2f}s; "
              f"decode {gen} toks: {t_decode:.2f}s "
              f"({gen * batch / max(t_decode, 1e-9):.1f} tok/s)")
    return {"tokens": np.asarray(out), "t_prefill": t_prefill,
            "t_decode": t_decode}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    out = run(args.arch, smoke=args.smoke, batch=args.batch,
              prompt_len=args.prompt_len, gen=args.gen,
              temperature=args.temperature)
    print("sample token ids:", out["tokens"][0, :10])


if __name__ == "__main__":
    main()
