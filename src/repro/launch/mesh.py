"""Production meshes for the TPU v5e deployment (see DESIGN.md §3).

Single pod: (data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16,
model=16) = 512 chips; the ``pod`` axis is the BHFL edge-server axis — the
slow, straggler-prone inter-pod link that HieAvg's hierarchy amortizes.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1, pod: int = 1
                    ) -> jax.sharding.Mesh:
    """Tiny mesh over however many devices exist (tests: 1 CPU device)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_sweep_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """1-D ``data`` mesh over all (or ``n_devices``) local devices.

    The sweep fabric shards the stacked grid-point axis over ``data``
    (``launch.sharding.SWEEP_RULES``); on one device this is a size-1 mesh
    and the placement layer degrades to plain ``vmap``.
    """
    n = n_devices if n_devices is not None else len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def mesh_axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1
