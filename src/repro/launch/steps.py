"""SPMD step functions: hierarchical FL training + serving.

Layout A (train): every parameter leaf is ``[E, C, *shape]`` — E pods
(edge servers), C clients per pod.  One ``hfl_train_step`` performs

  1. per-client local SGD (vmapped over E and C; remat'd forward),
  2. HieAvg edge aggregation over C   (all-reduce on the ``data`` axis),
  3. HieAvg global aggregation over E (all-reduce on the ``pod`` axis),
  4. broadcast of the global model back to every client slot.

This is the paper's full global round (K=1 compiled in-line; the driver
loops edge rounds and calls the global step every K-th round).  Straggler
masks are runtime inputs, so one compiled step serves any schedule.

Layout B (serve): plain parameter pytrees; ``prefill_step`` fills KV/state
caches, ``serve_step`` decodes ONE token against a ``seq_len`` cache.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import hieavg
from repro.models import ArchConfig, loss_fn, prefill, decode_step
from repro.models import moe as moe_mod
from repro.optim import sgd_step  # noqa: F401 (re-export for drivers)

PyTree = Any


def _set_moe_hint(cfg: ArchConfig, mesh) -> None:
    """Enable the GShard expert-parallel all-to-all when E divides the
    model axis (see models/moe.EXPERT_PARALLEL_SPEC), and the SP->TP
    head-sharded attention when the head counts divide it
    (models/attention.HEAD_SPEC)."""
    from repro.models import attention as att_mod
    if (mesh is not None and cfg.moe is not None
            and mesh.shape.get("model", 1) > 1
            and cfg.moe.n_experts % mesh.shape["model"] == 0):
        from jax.sharding import NamedSharding, PartitionSpec as P
        moe_mod.EXPERT_PARALLEL_SPEC = (
            NamedSharding(mesh, P(None, "model", None, None, None)),
            NamedSharding(mesh, P(None, None, "model", None, None)))
    else:
        moe_mod.EXPERT_PARALLEL_SPEC = None
    model_sz = mesh.shape.get("model", 1) if mesh is not None else 1
    kv_ok = (cfg.mla is not None) or (cfg.n_kv_heads % model_sz == 0)
    if model_sz > 1 and cfg.n_heads % model_sz == 0 and kv_ok:
        from jax.sharding import NamedSharding, PartitionSpec as P
        att_mod.HEAD_SPEC = NamedSharding(mesh, P(None, None, "model", None))
        att_mod.KV_GATHER_SPEC = None
    else:
        att_mod.HEAD_SPEC = None
        if (model_sz > 1 and cfg.mla is None
                and cfg.n_heads % model_sz != 0):
            # q-heads don't divide the model axis (qwen3-class): hoist the
            # K/V gather out of the q-chunk loop (one gather per layer).
            # Not for MLA (expanded per-head K too large to replicate) and
            # not for GQA archs whose q-heads do divide (grok: measured
            # +12% collectives) — see §Perf Q1.
            from jax.sharding import NamedSharding, PartitionSpec as P
            att_mod.KV_GATHER_SPEC = NamedSharding(mesh, P())
        else:
            att_mod.KV_GATHER_SPEC = None


# -------------------------------------------------------------- train (A)
def _per_client_grad(params: PyTree, tokens, labels, memory, cfg: ArchConfig,
                     remat: bool, act_spec=None):
    """loss/grad vmapped over the two FL dims. params leaves [E, C, ...]."""

    def one(p, t, l, m):
        return loss_fn(p, t, l, cfg, memory_embeds=m, remat=remat,
                       act_spec=act_spec)

    fn = jax.value_and_grad(one)
    fn = jax.vmap(fn)                     # over C
    fn = jax.vmap(fn)                     # over E
    return fn(params, tokens, labels, memory)


def make_hfl_train_step(cfg: ArchConfig, *, gamma0: float = 0.9,
                        lam: float = 0.9, do_global: bool = True,
                        remat: bool = True, normalize: bool = False,
                        mesh=None, n_micro: int = 1):
    """Returns step(params, dev_hist, glob_hist, batch, dev_mask, edge_mask,
    lr) -> (params, dev_hist, glob_hist, loss).

    ``dev_hist`` leaves [E, C, ...] (per-edge device histories);
    ``glob_hist`` leaves [E, ...] (edge-model history at the leader).
    ``batch``: dict(tokens [E,C,b,S], labels [E,C,b,S], memory optional).
    ``dev_mask`` [E, C] bool; ``edge_mask`` [E] bool.
    ``n_micro`` > 1 splits each client's batch into microbatches with
    gradient accumulation (mean) — same SGD math, 1/n_micro the
    activation working set.
    """

    edge_agg = jax.vmap(functools.partial(
        hieavg.edge_aggregate, gamma0=gamma0, lam=lam, normalize=normalize))

    _set_moe_hint(cfg, mesh)
    # explicit shardings for the microbatch grad accumulator — an
    # unconstrained zeros carry makes GSPMD re-gather every weight
    # gradient on every scan iteration (§Perf A2)
    from repro.models import param_specs as _pspecs
    if mesh is not None and n_micro > 1:
        from repro.launch import sharding as shd_mod
        e_sz = mesh.shape.get("pod", 1)
        grad_shardings = shd_mod.shard_specs(
            _pspecs(cfg), shd_mod.train_rules(cfg.clients_per_pod), mesh,
            prefix=((e_sz, "fl_pods"), (cfg.clients_per_pod, "fl_clients")))
    else:
        grad_shardings = jax.tree.map(
            lambda s: None, _pspecs(cfg),
            is_leaf=lambda x: hasattr(x, "axes"))
    act_spec = None
    if mesh is not None and mesh.shape.get("model", 1) > 1:
        from jax.sharding import NamedSharding, PartitionSpec as P
        # per-client activations [b, s, d]: shard s over the model axis
        # (sequence parallelism).  With one client per pod (grok-scale) the
        # data axis is free too — shard the big per-pod batch over it.
        bax = "data" if (cfg.clients_per_pod == 1
                         and mesh.shape.get("data", 1) > 1) else None
        act_spec = NamedSharding(mesh, P(bax, "model", None))

    def grads_of(params, tokens, labels, memory):
        if n_micro == 1:
            return _per_client_grad(params, tokens, labels, memory, cfg,
                                    remat, act_spec)
        e, c, b = tokens.shape[:3]
        mb = b // n_micro

        def split(t):
            if t is None:
                return None
            return jnp.moveaxis(
                t.reshape((e, c, n_micro, mb) + t.shape[3:]), 2, 0)

        def body(carry, xs):
            loss_acc, grad_acc = carry
            tk, lb, mem = xs
            loss, grads = _per_client_grad(params, tk, lb, mem, cfg,
                                           remat, act_spec)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, grad_acc, grads)), None

        def zeros_like_sharded(p, sh):
            z = jnp.zeros(p.shape, jnp.float32)
            return (jax.lax.with_sharding_constraint(z, sh)
                    if sh is not None else z)

        zero = (jnp.zeros(tokens.shape[:2], jnp.float32),
                jax.tree.map(zeros_like_sharded, params, grad_shardings))
        xs = (split(tokens), split(labels), split(memory))
        if memory is None:
            xs = (split(tokens), split(labels),
                  jnp.zeros((n_micro,), jnp.float32))  # dummy leaf

            def body(carry, xs):  # noqa: F811 — memory-free variant
                loss_acc, grad_acc = carry
                tk, lb, _ = xs
                loss, grads = _per_client_grad(params, tk, lb, None, cfg,
                                               remat, act_spec)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grad_acc, grads)), None
        (loss, grads), _ = jax.lax.scan(body, zero, xs)
        inv = 1.0 / n_micro
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def step(params, dev_hist, glob_hist, batch, dev_mask, edge_mask, lr):
        tokens, labels = batch["tokens"], batch["labels"]
        memory = batch.get("memory")
        loss, grads = grads_of(params, tokens, labels, memory)
        # local SGD (paper's optimizer; lr is the paper's decayed eta^{t,k})
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)

        # edge aggregation: HieAvg over the C clients of each pod
        edge_models, dev_hist = edge_agg(params, dev_mask, dev_hist)

        if do_global:
            # global aggregation over pods on the Raft leader (J_i equal
            # per pod here: every pod hosts C client groups)
            e = dev_mask.shape[0]
            j_per_edge = jnp.full((e,), dev_mask.shape[1], jnp.float32)
            global_model, glob_hist = hieavg.global_aggregate(
                edge_models, edge_mask, glob_hist, j_per_edge,
                gamma0=gamma0, lam=lam, normalize=normalize)
            # broadcast the new global model into every client slot
            c = dev_mask.shape[1]
            params = jax.tree.map(
                lambda g, p: jnp.broadcast_to(
                    g[None, None].astype(p.dtype), p.shape),
                global_model, params)
        else:
            # devices sync to their pod's edge model
            params = jax.tree.map(
                lambda em, p: jnp.broadcast_to(
                    em[:, None].astype(p.dtype), p.shape),
                edge_models, params)

        return params, dev_hist, glob_hist, jnp.mean(loss)

    return step


def init_fl_histories(params: PyTree) -> tuple[hieavg.History, hieavg.History]:
    """(dev_hist leaves [E,C,...], glob_hist leaves [E,...]) from Layout-A
    params — cold-boot initialization (Alg. 1)."""
    dev_hist = jax.vmap(hieavg.init_history)(params)
    edge0 = jax.tree.map(lambda p: jnp.mean(p.astype(jnp.float32), axis=1),
                         params)
    glob_hist = hieavg.init_history(edge0)
    return dev_hist, glob_hist


# -------------------------------------------------------------- serve (B)
def make_prefill_step(cfg: ArchConfig, mesh=None):
    """(params, tokens [B,S], caches, memory?) -> (logits [B,V], caches)."""
    _set_moe_hint(cfg, mesh)

    def step(params, tokens, caches, memory=None):
        return prefill(params, tokens, cfg, caches, memory_embeds=memory)

    return step


def make_serve_step(cfg: ArchConfig, mesh=None):
    """One-token decode: (params, token [B,1], pos, caches, memory?) ->
    (logits [B,V], new caches).  ``pos`` is the current absolute position
    (cache holds positions < pos)."""
    _set_moe_hint(cfg, mesh)

    def step(params, token, pos, caches, memory=None):
        return decode_step(params, token, pos, cfg, caches, memory=memory)

    return step


def make_train_step(cfg: ArchConfig, remat: bool = True):
    """Plain (non-FL) data-parallel train step for Layout B params —
    the W/O-stragglers oracle at datacenter scale, and the baseline the
    paper compares its hierarchy against."""

    def step(params, tokens, labels, lr, memory=None):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, tokens, labels, cfg, memory_embeds=memory, remat=remat)
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return params, loss

    return step
