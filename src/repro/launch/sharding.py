"""Logical-axis → mesh-axis rules (the GSPMD layer).

Models annotate parameters with *logical* axis names (see
``repro.models.spec``).  This module maps them to mesh axes per runtime
layout, with automatic fallback: a logical axis is sharded only when the
dimension is divisible by the mesh-axis extent and the mesh axis is not
already consumed by another dimension of the same tensor — so GQA archs
with 8 (or 1) KV heads on a 16-way model axis degrade to replicated KV
projections instead of failing to lower.

Layouts
-------
* ``train`` (Layout A, hierarchical FL): every parameter leaf carries two
  leading FL dims ``[n_pods, clients_per_pod, ...]`` — logical axes
  ``fl_pods`` / ``fl_clients`` — sharded over ``pod`` / ``data``.  Inner
  dims use tensor-parallel rules over ``model``.
* ``train_fl1`` (grok-scale): one client per pod; the dead ``fl_clients``
  dim frees the ``data`` axis for FSDP over ``embed``.
* ``serve`` (Layout B): no FL dims; 2D weight sharding (``embed``→data,
  matmul dims→model); activations/caches shard batch over pod+data.
"""
from __future__ import annotations

from typing import Any, Optional, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.dispatch import resolve_kernel_mode  # noqa: F401
from repro.models import ParamSpec
from repro.models.spec import PyTree

# ``resolve_kernel_mode`` is re-exported here on purpose: the launch layer
# resolves WHERE a program runs (mesh placement, the autoscaling specs
# below) and HOW its hot loops execute (kernel-plane backend — compiled
# Pallas on TPU/GPU, XLA reference on CPU) side by side, from the same
# runtime facts.  The policy itself lives in ``repro.kernels.dispatch`` so
# the kernel plane stays self-contained.

Axis = Union[str, tuple]        # one candidate: mesh axis or axis tuple
Rule = tuple                    # priority-ordered candidates

# ------------------------------------------------------------------ rules
_TP = {
    "mlp": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "experts": (("model",),),
    "vocab": (("model",),),
    "layers": (),
    "embed": (),
}

TRAIN_RULES = {
    "fl_pods": (("pod",),),
    "fl_clients": (("data",),),
    "act_batch": (),            # per-client batch stays local
    **_TP,
}

# grok-scale: 1 client per pod -> data axis does FSDP over embed instead
TRAIN_RULES_FL1 = {
    "fl_pods": (("pod",),),
    "fl_clients": (),
    "act_batch": (),
    **{**_TP, "embed": (("data",),)},
}

SERVE_RULES = {
    "fl_pods": (),
    "fl_clients": (),
    "act_batch": (("pod", "data"), ("data",)),
    "kv_seq": (("model",),),    # secondary: only if kv_heads can't use it
    **{**_TP, "embed": (("data",),)},
}

# sweep fabric: the stacked grid-point axis of a batched BHFL sweep
# (repro.fl.sweep).  Prefers the full pod×data product when pods exist,
# otherwise the data axis; the usual divisibility contract applies, so an
# indivisible or single-device bucket degrades to the vmap path instead of
# failing to lower (per shape bucket — each bucket of a plan resolves its
# own spec from its own point count).  Every stacked EngineInputs plane
# rides this one axis — including the latency fabric's per-round
# ``dev_time``/``cons_time`` draws (PR 3), so a consensus-latency×topology
# grid shards its time accounting alongside its training data with no
# extra rules.  The one exception is the seed-major data plane
# (``engine.SHARED_DATA_FIELDS``): train/test/init arrays carry a
# ``[n_seeds]`` seed axis instead of the point axis and are replicated on
# every device (``sweep_data_spec``) — device-resident data scales with
# distinct seeds, not grid points.
SWEEP_RULES = {
    "sweep_points": (("pod", "data"), ("data",)),
}

# logical axes resolved in a second pass, after the primary dims have had
# first pick of the mesh axes (e.g. kv_seq takes "model" only when the
# arch's kv_heads count is not divisible by the model-axis extent)
SECONDARY_AXES = frozenset({"kv_seq"})


def train_rules(clients_per_pod: int) -> dict:
    return TRAIN_RULES_FL1 if clients_per_pod == 1 else TRAIN_RULES


def sweep_spec(n_points: int, mesh: Mesh) -> P:
    """PartitionSpec for a sweep's stacked point axis on ``mesh``.

    ``P()`` (replicated) means the autoscaling contract chose the
    single-device path: the point count does not divide any candidate mesh
    axis, or the mesh has no >1 sweep-capable axis — callers fall back to
    ``vmap`` exactly as ``resolve_spec`` degrades undersized kv heads.
    """
    return resolve_spec((n_points,), ("sweep_points",), SWEEP_RULES, mesh)


def sweep_data_spec() -> P:
    """PartitionSpec for the sweep fabric's seed-major data plane.

    The train/test/init arrays of a sweep are stacked over *distinct
    seeds* (``[n_seeds, ...]``), not grid points, and every point gathers
    its row by ``seed_idx`` inside the engine — so the plane is replicated
    across the mesh (``P()``) rather than sharded with the point axis.
    Kept as a named helper (not a bare ``P()`` at the call site) so the
    data-plane placement contract has exactly one home.
    """
    return P()


# ------------------------------------------------------------- resolution
def _axes_size(mesh: Mesh, cand) -> int:
    return int(np.prod([mesh.shape[a] for a in cand])) if cand else 1


def resolve_spec(shape: tuple, axes: tuple, rules: dict, mesh: Mesh) -> P:
    """Pick mesh axes per dim: first divisible, unused candidate wins.

    Two passes: primary logical axes first, then SECONDARY_AXES claim
    whatever mesh axes remain (kv_seq fallback for undersized kv_heads).
    """
    used: set = set()
    out: list = [None] * len(shape)

    def try_dim(i, dim, name):
        for cand in rules.get(name, ()):
            cand = tuple(a for a in cand if a in mesh.shape)
            if not cand or any(a in used for a in cand):
                continue
            size = _axes_size(mesh, cand)
            if size > 1 and dim % size == 0:
                out[i] = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                return

    for i, (dim, name) in enumerate(zip(shape, axes)):
        if name is not None and name not in SECONDARY_AXES:
            try_dim(i, dim, name)
    for i, (dim, name) in enumerate(zip(shape, axes)):
        if name in SECONDARY_AXES:
            try_dim(i, dim, name)
    # trim trailing Nones (canonical PartitionSpec form)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard_specs(specs: PyTree, rules: dict, mesh: Mesh,
                prefix: tuple[tuple[int, str], ...] = ()) -> PyTree:
    """ParamSpec pytree -> NamedSharding pytree.

    ``prefix``: extra leading (size, logical_name) dims prepended to every
    leaf — the FL client dims of Layout A.
    """
    pshape = tuple(s for s, _ in prefix)
    paxes = tuple(a for _, a in prefix)

    def one(s: ParamSpec) -> NamedSharding:
        return NamedSharding(mesh, resolve_spec(
            pshape + s.shape, paxes + s.axes, rules, mesh))

    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def shard_abstract(specs: PyTree, rules: dict, mesh: Mesh,
                   prefix: tuple[tuple[int, str], ...] = (),
                   dtype=None) -> tuple[PyTree, PyTree]:
    """(ShapeDtypeStruct pytree with shardings attached, sharding pytree).

    The FL prefix dims are materialized into the struct shapes.
    """
    pshape = tuple(s for s, _ in prefix)
    shardings = shard_specs(specs, rules, mesh, prefix)

    def one(s: ParamSpec, sh: NamedSharding):
        return jax.ShapeDtypeStruct(pshape + s.shape, dtype or s.dtype,
                                    sharding=sh)

    structs = jax.tree.map(one, specs, shardings,
                           is_leaf=lambda x: isinstance(x, ParamSpec))
    return structs, shardings


def data_sharding(mesh: Mesh, *spec: Any) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def batch_axes(mesh: Mesh) -> tuple:
    """The composite batch axis: ("pod","data") when pods exist."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)
