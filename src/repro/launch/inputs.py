"""``input_specs()`` — ShapeDtypeStruct stand-ins (with shardings) for every
model input, per (architecture × input shape × mesh).  No device allocation:
these feed ``jax.jit(step).lower()`` directly.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.hieavg import History
from repro.models import ArchConfig, InputShape, cache_specs, param_specs
from repro.launch import sharding as shd

PyTree = Any


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def fl_dims(cfg: ArchConfig, shape: InputShape, mesh) -> tuple[int, int, int]:
    """(E pods, C clients/pod, per-client batch)."""
    e = shd.mesh_axis_size(mesh, "pod") if hasattr(shd, "mesh_axis_size") \
        else mesh.shape.get("pod", 1)
    c = cfg.clients_per_pod
    b = max(shape.global_batch // (e * c), 1)
    return e, c, b


def _memory_shape(cfg: ArchConfig) -> Optional[tuple[int, int]]:
    """(frames, d_model) of the stubbed modality frontend, if any."""
    if cfg.encoder is not None:
        return cfg.encoder.n_frames, cfg.d_model
    if "xattn" in cfg.block_pattern:
        return cfg.n_image_tokens, cfg.d_model
    return None


# ------------------------------------------------------------------ train
# History storage dtype override (beyond-paper, §Perf X1): float8_e4m3fn
# halves HieAvg's 4-extra-model-copies cost; None = parameter dtype.
HIST_DTYPE = None


def train_input_specs(cfg: ArchConfig, shape: InputShape, mesh) -> dict:
    """Inputs of ``make_hfl_train_step``'s step function (Layout A)."""
    assert shape.kind == "train", shape
    e, c, b = fl_dims(cfg, shape, mesh)
    rules = shd.train_rules(cfg.clients_per_pod)
    prefix = ((e, "fl_pods"), (c, "fl_clients"))
    dt = cfg.jnp_param_dtype
    hdt = HIST_DTYPE or dt

    params, _ = shd.shard_abstract(param_specs(cfg), rules, mesh,
                                   prefix=prefix, dtype=dt)
    hist_params, _ = shd.shard_abstract(param_specs(cfg), rules, mesh,
                                        prefix=prefix, dtype=hdt)
    glob_params, _ = shd.shard_abstract(param_specs(cfg), rules, mesh,
                                        prefix=((e, "fl_pods"),), dtype=hdt)

    pod_ax = "pod" if "pod" in mesh.shape else None
    cli_ax = "data" if cfg.clients_per_pod > 1 else None
    bat_ax = "data" if cfg.clients_per_pod == 1 else None
    tok = _sds((e, c, b, shape.seq_len), jnp.int32, mesh,
               P(pod_ax, cli_ax, bat_ax))
    batch = {"tokens": tok, "labels": tok}
    mem = _memory_shape(cfg)
    if mem is not None:
        batch["memory"] = _sds((e, c, b) + mem, dt, mesh,
                               P(pod_ax, cli_ax, bat_ax))

    def hist_of(tree, n_shape, n_spec):
        return History(
            prev_w=tree, delta_mean=tree,
            n_obs=_sds(n_shape, jnp.float32, mesh, n_spec),
            miss_count=_sds(n_shape, jnp.float32, mesh, n_spec))

    dev_hist = hist_of(hist_params, (e, c), P(pod_ax, cli_ax))
    glob_hist = hist_of(glob_params, (e,), P(pod_ax))

    return dict(
        params=params,
        dev_hist=dev_hist,
        glob_hist=glob_hist,
        batch=batch,
        dev_mask=_sds((e, c), jnp.bool_, mesh, P(pod_ax, cli_ax)),
        edge_mask=_sds((e,), jnp.bool_, mesh, P(pod_ax)),
        lr=jax.ShapeDtypeStruct((), jnp.float32),
    )


# ------------------------------------------------------------------ serve
def serve_param_specs(cfg: ArchConfig, mesh) -> PyTree:
    dt = cfg.jnp_param_dtype
    params, _ = shd.shard_abstract(param_specs(cfg), shd.SERVE_RULES, mesh,
                                   dtype=dt)
    return params


def serve_input_specs(cfg: ArchConfig, shape: InputShape, mesh) -> dict:
    """Inputs of prefill_step (kind=prefill) / serve_step (kind=decode)."""
    b = shape.global_batch
    dt = cfg.jnp_param_dtype
    params = serve_param_specs(cfg, mesh)

    cs = cache_specs(cfg, b, shape.seq_len, dtype=dt)
    caches, _ = shd.shard_abstract(cs, shd.SERVE_RULES, mesh)

    bspec = shd.resolve_spec((b,), ("act_batch",), shd.SERVE_RULES, mesh)
    bax = bspec[0] if len(bspec) else None

    out = dict(params=params, caches=caches)
    mem = _memory_shape(cfg)
    if shape.kind == "prefill":
        out["tokens"] = _sds((b, shape.seq_len), jnp.int32, mesh, P(bax))
    else:
        out["token"] = _sds((b, 1), jnp.int32, mesh, P(bax))
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    if mem is not None:
        # decode consumes *pre-encoded* memory (encoder runs at prefill)
        out["memory"] = _sds((b,) + mem, dt, mesh, P(bax))
    return out


def input_specs(cfg: ArchConfig, shape: InputShape, mesh) -> dict:
    if shape.kind == "train":
        return train_input_specs(cfg, shape, mesh)
    return serve_input_specs(cfg, shape, mesh)


# -------------------------------------------------------- output shardings
def _sharding_like(struct_tree) -> PyTree:
    """Extract the NamedSharding pytree from sharding-attached SDS leaves."""
    return jax.tree.map(lambda s: s.sharding, struct_tree)


def output_shardings(cfg: ArchConfig, shape: InputShape, mesh):
    """Explicit out_shardings for the step compiled by the dry-run.

    Without these, GSPMD is free to replicate the broadcast global model
    back into the [E, C, ...] client slots, inflating per-device output
    bytes by ExC.
    """
    specs = input_specs(cfg, shape, mesh)
    if shape.kind == "train":
        scalar = NamedSharding(mesh, P())
        return (_sharding_like(specs["params"]),
                _sharding_like(specs["dev_hist"]),
                _sharding_like(specs["glob_hist"]),
                scalar)
    b = shape.global_batch
    logits_spec = shd.resolve_spec((b, cfg.vocab), ("act_batch", "vocab"),
                                   shd.SERVE_RULES, mesh)
    logits = NamedSharding(mesh, logits_spec)
    return (logits, _sharding_like(specs["caches"]))
