"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2 [arXiv:2402.19427].

38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000.
Griffin pattern: (recurrent, recurrent, local-attention) repeated; 38 layers
= 12 full units + a trailing (rec, rec) tail.  Local attention window 2048.
Sub-quadratic → runs long_500k.
"""
from repro.models import ArchConfig, RGLRUConfig

FULL = ArchConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    sliding_window=2048,
    rglru=RGLRUConfig(lru_width=4096),
    block_pattern=("rec", "rec", "attn"),
    tail_pattern=("rec", "rec"),
    tie_embeddings=True,
    subquadratic=True,
    source="RecurrentGemma-9B [arXiv:2402.19427]",
    clients_per_pod=16,
)


def make_smoke() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, name="recurrentgemma-smoke", n_layers=5, d_model=128, n_heads=4,
        n_kv_heads=1, d_ff=256, vocab=512, param_dtype="float32",
        sliding_window=16, rglru=RGLRUConfig(lru_width=128))
