"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768 (attention-free) d_ff=0 vocab=50280, ssm_state=128.
Sub-quadratic (O(1)-state decode) → runs long_500k.
"""
from repro.models import ArchConfig, SSMConfig

FULL = ArchConfig(
    name="mamba2-130m",
    arch_type="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,           # unused by the SSD mixer (heads come from SSMConfig)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    block_pattern=("ssd",),
    tie_embeddings=True,
    subquadratic=True,
    source="Mamba2-130M [arXiv:2405.21060]",
    clients_per_pod=16,
)


def make_smoke() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, name="mamba2-smoke", n_layers=2, d_model=128, vocab=512,
        param_dtype="float32",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16))
