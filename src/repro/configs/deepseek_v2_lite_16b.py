"""deepseek-v2-lite-16b [moe] — MLA + MoE [arXiv:2405.04434].

27L d_model=2048 16H (GQA kv=16) d_ff=1408 (per-expert) vocab=102400,
MoE 64 routed experts top-6 + 2 shared, MLA kv_lora_rank=512.

The assignment line reads "MoE 64e top-6 ... 2 shared+160 routed top-6";
160 routed is full DeepSeek-V2 — we follow the V2-*Lite* spec the
architecture id names: 64 routed experts (see DESIGN.md §Arch-applicability).
MLA in Lite has no q compression (q_lora_rank=None), qk_nope=128, rope=64,
v_head_dim=128.
"""
from repro.models import ArchConfig, MLAConfig, MoEConfig

FULL = ArchConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    mla=MLAConfig(q_lora_rank=None, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    block_pattern=("mla_moe",),
    source="DeepSeek-V2-Lite [arXiv:2405.04434]",
    clients_per_pod=16,   # must divide the 16-wide data axis
)


def make_smoke() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, name="dsv2-lite-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=512, param_dtype="float32",
        mla=MLAConfig(q_lora_rank=None, kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=64,
                      capacity_factor=16.0))  # drop-free for exactness tests
