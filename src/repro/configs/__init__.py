"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

Every assigned architecture is a selectable config (``--arch <id>`` in the
launch scripts).  IDs use the assignment spelling (dashes/dots).
"""
from __future__ import annotations

import importlib

from repro.models import ArchConfig

_MODULES = {
    "deepseek-7b": "deepseek_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "minicpm3-4b": "minicpm3_4b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "grok-1-314b": "grok_1_314b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen3-14b": "qwen3_14b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "mamba2-130m": "mamba2_130m",
}

ARCH_IDS = tuple(_MODULES)


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ArchConfig:
    """Full (production) config for an assigned architecture."""
    return _mod(arch_id).FULL


def get_smoke(arch_id: str) -> ArchConfig:
    """Reduced same-family variant (≤2-5 layers, d_model≤512, ≤4 experts)."""
    return _mod(arch_id).make_smoke()


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
