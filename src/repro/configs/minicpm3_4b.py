"""minicpm3-4b [dense] — MLA [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448.
MLA dims from the model card: q_lora_rank=768, kv_lora_rank=256,
qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64.
"""
from repro.models import ArchConfig, MLAConfig

FULL = ArchConfig(
    name="minicpm3-4b",
    arch_type="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    block_pattern=("mla",),
    tie_embeddings=True,
    source="MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]",
    clients_per_pod=16,
)


def make_smoke() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, name="minicpm3-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab=512, param_dtype="float32",
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16))
