"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  Every 5th layer is
a gated cross-attention layer against vision-projector patch embeddings
(8 of 40).  The ViT encoder + projector are a STUB — ``input_specs()``
provides precomputed patch embeddings [B, n_image_tokens, D].
"""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=500000.0,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    n_image_tokens=1601,
    source="Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision]",
    clients_per_pod=16,
)


def make_smoke() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, name="llama-vision-smoke", n_layers=5, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, param_dtype="float32",
        n_image_tokens=16)
