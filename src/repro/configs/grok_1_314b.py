"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.

At 314B parameters one FL client is an entire pod (clients_per_pod=1):
the client's weights are FSDP+TP sharded over all 256 in-pod chips.
"""
from repro.models import ArchConfig, MoEConfig

FULL = ArchConfig(
    name="grok-1-314b",
    arch_type="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_expert=32768),
    block_pattern=("attn_moe",),
    source="Grok-1 [hf:xai-org/grok-1]",
    clients_per_pod=1,
)


def make_smoke() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, name="grok-1-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, param_dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_expert=256,
                      capacity_factor=16.0))  # drop-free for exactness tests
