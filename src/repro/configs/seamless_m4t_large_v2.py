"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596].

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.  The transformer
BACKBONE only: the mel-spectrogram + conv feature extractor frontend is a
stub — ``input_specs()`` provides precomputed frame embeddings [B, 1500, D].

Decoder layers alternate self-attention and cross-attention (each with its
own MLP), giving 24 backbone layers; a 24-layer encoder stack consumes the
stubbed frame embeddings.
"""
from repro.models import ArchConfig, EncoderConfig

FULL = ArchConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    block_pattern=("attn", "xattn"),
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
    source="Seamless-M4T v2 large [arXiv:2308.11596]",
    clients_per_pod=16,
)


def make_smoke() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, name="seamless-m4t-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab=512, param_dtype="float32",
        encoder=EncoderConfig(n_layers=2, n_frames=16))
