"""h2o-danube-1.8b [dense] — llama+mistral mix, SWA [arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, sliding window 4096.
Sub-quadratic (windowed attention) → runs long_500k.
"""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="h2o-danube-1.8b",
    arch_type="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    sliding_window=4096,
    block_pattern=("attn",),
    subquadratic=True,
    source="H2O-Danube-1.8B [arXiv:2401.16818]",
    clients_per_pod=16,
)


def make_smoke() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, name="danube-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, sliding_window=16,
        param_dtype="float32")
