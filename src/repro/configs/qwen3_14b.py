"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="qwen3-14b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    head_dim=128,
    rope_theta=1000000.0,
    block_pattern=("attn",),
    source="Qwen3-14B [hf:Qwen/Qwen3-8B]",
    clients_per_pod=16,
)


def make_smoke() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, name="qwen3-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, param_dtype="float32")
