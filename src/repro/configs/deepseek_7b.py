"""deepseek-7b [dense] — llama-arch [arXiv:2401.02954].

30L d_model=4096 32H (GQA kv=32 = MHA) d_ff=11008 vocab=102400.
"""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="deepseek-7b",
    arch_type="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    rope_theta=10000.0,
    block_pattern=("attn",),
    source="DeepSeek LLM 7B [arXiv:2401.02954]",
    clients_per_pod=16,
)


def make_smoke() -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests."""
    import dataclasses
    return dataclasses.replace(
        FULL, name="deepseek-7b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab=512, param_dtype="float32")
