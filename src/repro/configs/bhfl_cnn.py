"""The paper's own experimental model (Sec. 6.1.5): a small CNN for the
MNIST-surrogate BHFL experiments — 2 conv layers, 1 max-pool, 1 dense.

Not part of the assigned-architecture grid; used by the FL simulator and
the Fig. 2-7 benchmark repros.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class BHFLSetting:
    """Sec. 6.1.1 basic setting."""
    n_edges: int = 5
    j_per_edge: int = 5
    k_edge_rounds: int = 2          # K
    t_global_rounds: int = 50       # T
    t_cold_boot: int = 2            # T_c
    gamma0: float = 0.9
    lam: float = 0.9
    lr0: float = 1e-3
    lr_decay: float = 0.90
    batch_size: int = 32
    straggler_frac: float = 0.2     # 20% per layer
    image_hw: int = 28
    cnn_c1: int = 32                # paper's conv widths (Sec. 6.1.5)
    cnn_c2: int = 64
    n_classes: int = 10
    classes_per_device: int = 1     # non_IID_1
    permanent_stop_round: int = 40
    seed: int = 0
    # --- latency fabric (Sec. 5 / Sec. 6.2.2 measured constants).  These
    # are data-batched sweep fields: the engine precomputes per-round time
    # draws from them, so a consensus-latency x topology grid is one
    # compiled call (see repro.fl.sweep.BATCHED_FIELDS).
    lm_device: float = 0.51         # E[LM]  device<->edge one-way (s)
    lp_device: float = 1.67         # E[LP]  local training per edge round
    lm_edge: float = 0.05           # E[LM'] edge<->leader one-way
    link_latency: float = 0.05      # Raft edge<->edge message (s)
    consensus_mult: float = 1.0     # scales the drawn per-round L_bc
    # --- consensus zoo (repro.core.consensus).  Both are data-batched
    # sweep fields: the protocol only changes the host-side chain replay
    # feeding the cons_time/cons_energy planes, so a mixed-consensus grid
    # compiles as one padded call.
    consensus: str = "raft"         # "raft" | "pofel" | "sharded"
    n_shards: int = 2               # sharded-chain committee count
    # --- delayed-gradient aggregation (aggregator="delayed_grad"; see
    # core.baselines.delayed_grad).  Data-batched sweep fields like the
    # latency constants: a staleness-discount grid is one compiled call.
    staleness_discount: float = 0.9  # beta — stale update weight beta**k'
    delay_delta: int = 1            # max consecutive-miss staleness; k' >
    #   delta drops the slot from the round's aggregate entirely
    # --- fault plane (repro.fl.faults).  All data-batched sweep fields:
    # faults only change host-side planes (submission masks, the replayed
    # chain's alive set and cons_time/cons_energy draws), never array
    # shapes, so a fault-rate x consensus grid compiles as one padded call.
    # Rates are per-round transition probabilities of two-state Markov
    # crash-recover processes (rate = 1/MTBF resp. 1/MTTR in rounds).
    edge_fail_rate: float = 0.0     # P[edge up -> down] per global round
    edge_recover_rate: float = 0.0  # P[edge down -> up]; 0 = never recover
    val_fail_rate: float = 0.0      # P[chain validator up -> down] per tick
    val_recover_rate: float = 0.0   # P[validator down -> up] per tick
    burst_prob: float = 0.0         # P[correlated device-outage burst] per
    #   (global round, edge): a burst masks burst_frac of the edge's
    #   devices out for that whole round
    burst_frac: float = 0.5         # fraction of devices a burst takes out
    msg_loss_prob: float = 0.0      # P[a submission message is lost], iid
    #   per device edge-round submission and per edge global submission
    max_stall_rounds: int = 0       # below-quorum consensus: bounded
    #   stall-and-retry attempts before raising (0 = immediate raise)
    stall_backoff: float = 0.5      # seconds of backoff for the first
    #   stall retry; doubles per attempt (C2-style stall in the clock)


DEFAULT = BHFLSetting()

# CPU-budget setting for the benchmark repros: same topology/rounds as the
# paper, smaller images/CNN so a full Fig. 2 sweep runs in minutes.  The
# paper's qualitative claims (straggler robustness ordering, K/J/N trends)
# are width-independent.
REDUCED = BHFLSetting(image_hw=14, cnn_c1=8, cnn_c2=16, batch_size=16,
                      lr0=0.02, lr_decay=0.3)

