"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block = temporal conv1d (width 4) -> gated linear recurrence:

    r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is linear in h, so train/prefill use a parallel associative
scan over time (log-depth on TPU); decode is the one-step update.  The full
block is the Griffin "recurrent block": two branches (gate + recurrence) and
an output projection, residual added by the caller pattern.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import norm_spec, rms_norm
from .spec import ParamSpec


def rglru_specs(cfg: ArchConfig, stacked: Optional[int]) -> dict:
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    d = cfg.d_model
    return {
        "w_in": ParamSpec(pre_s + (d, w), pre_a + ("embed", "mlp")),
        "w_gate": ParamSpec(pre_s + (d, w), pre_a + ("embed", "mlp")),
        "conv_w": ParamSpec(pre_s + (r.d_conv, w), pre_a + (None, "mlp")),
        "w_a": ParamSpec(pre_s + (w, w), pre_a + ("mlp", None)),
        "w_i": ParamSpec(pre_s + (w, w), pre_a + ("mlp", None)),
        "lam": ParamSpec(pre_s + (w,), pre_a + (None,), init="ones"),
        "w_out": ParamSpec(pre_s + (w, d), pre_a + ("mlp", "embed")),
        "norm": norm_spec(d, pre_a, pre_s),
    }


def _gates(p: dict, u: jnp.ndarray, cfg: ArchConfig):
    """a_t (log-space) and gated input for the recurrence."""
    r = cfg.rglru
    rec_gate = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_a"]))
    in_gate = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_i"]))
    log_a = -r.c_constant * jax.nn.softplus(p["lam"]) * rec_gate.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (in_gate * u).astype(jnp.float32)
    return a, gated_x


def _conv(p: dict, u: jnp.ndarray, state: Optional[jnp.ndarray] = None):
    """Causal depthwise conv over time. u: [B,S,W]. state: [B,d_conv-1,W]."""
    k = p["conv_w"].shape[0]
    pad = state if state is not None else jnp.zeros(
        u.shape[:-2] + (k - 1, u.shape[-1]), u.dtype)
    full = jnp.concatenate([pad, u], axis=-2)
    out = sum(full[..., i:i + u.shape[-2], :] * p["conv_w"][i] for i in range(k))
    new_state = full[..., -(k - 1):, :]
    return out, new_state


SCAN_CHUNK = 256


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, b1 * a2 + b2


def linear_scan(a: jnp.ndarray, gx: jnp.ndarray, h0=None,
                chunk: int = SCAN_CHUNK):
    """h_t = a_t * h_{t-1} + gx_t along axis -2, chunked.

    a/gx: [B, S, W] (f32).  A full-sequence associative scan materializes
    O(log S) copies of [B, S, W] — tens of GB at 4k x 4096; chunking caps
    the working set at [B, chunk, W] * log(chunk) with a tiny [B, W] carry
    across chunks.  Returns (h [B, S, W], h_final [B, W]).
    """
    b, s, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)
    pad = (-s) % chunk
    if pad:  # pad with identity elements (a=1, gx=0)
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        gx = jnp.pad(gx, ((0, 0), (0, pad), (0, 0)))
    nc = a.shape[1] // chunk
    ac = jnp.moveaxis(a.reshape(b, nc, chunk, w), 1, 0)    # [nc,B,C,W]
    gc = jnp.moveaxis(gx.reshape(b, nc, chunk, w), 1, 0)

    def outer(h, xs):
        a_c, g_c = xs                                       # [B, C, W]
        A, H = jax.lax.associative_scan(_combine, (a_c, g_c), axis=-2)
        H = H + A * h[:, None, :]
        return H[:, -1, :], H

    h_fin, hs = jax.lax.scan(outer, h0, (ac, gc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, nc * chunk, w)[:, :s]
    return hs, h_fin


def rglru_train(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Full-sequence recurrent block via chunked linear scan. x: [B,S,D]."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    u = jnp.einsum("...d,dw->...w", h, p["w_in"])
    gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", h, p["w_gate"]))
    u, _ = _conv(p, u)
    a, gx = _gates(p, u, cfg)
    h_s, _ = linear_scan(a, gx)
    out = (h_s.astype(x.dtype) * gate)
    return x + jnp.einsum("...w,wd->...d", out, p["w_out"])


def rglru_cache_spec(cfg: ArchConfig, batch: int, stacked: Optional[int],
                     dtype=jnp.float32) -> dict:
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    return {
        "h": ParamSpec(pre_s + (batch, w), pre_a + ("act_batch", "mlp"),
                       dtype, "zeros"),
        "conv": ParamSpec(pre_s + (batch, r.d_conv - 1, w),
                          pre_a + ("act_batch", None, "mlp"), dtype, "zeros"),
    }


def rglru_prefill(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict
                  ) -> tuple[jnp.ndarray, dict]:
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    u = jnp.einsum("...d,dw->...w", h, p["w_in"])
    gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", h, p["w_gate"]))
    u, conv_state = _conv(p, u)
    a, gx = _gates(p, u, cfg)
    h_s, h_fin = linear_scan(a, gx, cache["h"].astype(jnp.float32))
    out = (h_s.astype(x.dtype) * gate)
    new_cache = {"h": h_fin.astype(cache["h"].dtype),
                 "conv": conv_state.astype(cache["conv"].dtype)}
    return x + jnp.einsum("...w,wd->...d", out, p["w_out"]), new_cache


def rglru_decode(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict
                 ) -> tuple[jnp.ndarray, dict]:
    """One-step recurrence. x: [B,1,D]."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    u = jnp.einsum("...d,dw->...w", h, p["w_in"])
    gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", h, p["w_gate"]))
    u, conv_state = _conv(p, u, cache["conv"].astype(u.dtype))
    a, gx = _gates(p, u, cfg)
    h_new = a[..., 0, :] * cache["h"].astype(jnp.float32) + gx[..., 0, :]
    out = (h_new[..., None, :].astype(x.dtype) * gate)
    new_cache = {"h": h_new.astype(cache["h"].dtype),
                 "conv": conv_state.astype(cache["conv"].dtype)}
    return x + jnp.einsum("...w,wd->...d", out, p["w_out"]), new_cache
