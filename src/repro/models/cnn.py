"""The paper's MNIST CNN (Sec. 6.1.5): two conv layers, one max-pool, one
flatten, one dense layer.  Used by the BHFL simulator and Fig. 2-6 repros."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .spec import ParamSpec


def cnn_specs(image_hw: int = 28, channels: int = 1, n_classes: int = 10,
              c1: int = 32, c2: int = 64) -> dict:
    pooled = image_hw // 2  # one 2x2 max-pool after the convs (SAME padding)
    flat = pooled * pooled * c2
    return {
        "conv1": ParamSpec((3, 3, channels, c1), (None, None, None, None)),
        "b1": ParamSpec((c1,), (None,), init="zeros"),
        "conv2": ParamSpec((3, 3, c1, c2), (None, None, None, None)),
        "b2": ParamSpec((c2,), (None,), init="zeros"),
        "dense": ParamSpec((flat, n_classes), (None, None)),
        "b3": ParamSpec((n_classes,), (None,), init="zeros"),
    }


def _conv3x3_same(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """3x3 SAME conv via im2col + einsum.

    Pure dot-products instead of lax.conv: XLA:CPU's batched conv gradients
    (batch_group_count under vmap) are orders of magnitude slower than the
    equivalent matmul, and the FL simulator vmaps over dozens of devices.
    x: [..., H, W, Cin]; w: [3, 3, Cin, Cout].
    """
    h, wd = x.shape[-3], x.shape[-2]
    pad = [(0, 0)] * (x.ndim - 3) + [(1, 1), (1, 1), (0, 0)]
    xp = jnp.pad(x, pad)
    # sum of 9 shifted matmuls — no 9x im2col memory blowup
    out = None
    for i in range(3):
        for j in range(3):
            term = jnp.einsum("...c,co->...o",
                              xp[..., i:i + h, j:j + wd, :], w[i, j])
            out = term if out is None else out + term
    return out


def _conv3x3_same_im2col(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """3x3 SAME conv as ONE batched matmul (im2col).

    Costs 9x activation memory vs the shifted-sum form but issues a single
    large dot the backend can block efficiently — ~1.6x faster end-to-end on
    the vmapped FL training step at the paper's model sizes (EXPERIMENTS.md
    §Perf).  Same math as ``_conv3x3_same`` up to summation order; the
    batched engine trains with this form, the legacy reference loop keeps
    the shifted sum.  x: [..., H, W, Cin]; w: [3, 3, Cin, Cout].
    """
    h, wd = x.shape[-3], x.shape[-2]
    pad = [(0, 0)] * (x.ndim - 3) + [(1, 1), (1, 1), (0, 0)]
    xp = jnp.pad(x, pad)
    # (i, j, c)-ordered patch channels match w.reshape(9*Cin, Cout)
    cols = jnp.concatenate([xp[..., i:i + h, j:j + wd, :]
                            for i in range(3) for j in range(3)], axis=-1)
    return jnp.einsum("...k,ko->...o", cols, w.reshape(-1, w.shape[-1]))


def _pool_flatten(x: jnp.ndarray) -> jnp.ndarray:
    # 2x2 stride-2 max-pool via reshape — identical to reduce_window but its
    # gradient avoids SelectAndScatter, which is pathologically slow on CPU.
    b, h, w_, c = x.shape
    x = x.reshape(b, h // 2, 2, w_ // 2, 2, c).max(axis=(2, 4))
    return x.reshape(x.shape[0], -1)


def _apply(params: dict, images: jnp.ndarray, conv) -> jnp.ndarray:
    x = images
    for w, b in ((params["conv1"], params["b1"]),
                 (params["conv2"], params["b2"])):
        x = jax.nn.relu(conv(x, w) + b)
    x = _pool_flatten(x)
    return x @ params["dense"] + params["b3"]


def _features_fused(params: dict, images: jnp.ndarray, kernel_mode: str
                    ) -> jnp.ndarray:
    """Pooled/flattened features with the conv blocks kernel-routed
    (``kernels.dispatch.conv3x3_bias_relu`` — fused matmul+bias+ReLU)."""
    from repro.kernels import dispatch as _kd
    x = images
    for w, b in ((params["conv1"], params["b1"]),
                 (params["conv2"], params["b2"])):
        x = _kd.conv3x3_bias_relu(x, w, b, mode=kernel_mode)
    return _pool_flatten(x)


def cnn_apply(params: dict, images: jnp.ndarray) -> jnp.ndarray:
    """images [B, H, W, C] -> logits [B, n_classes]."""
    return _apply(params, images, _conv3x3_same)


def cnn_apply_fast(params: dict, images: jnp.ndarray,
                   kernel_mode: str = "xla") -> jnp.ndarray:
    """``cnn_apply`` with the im2col conv — the engine's training path.

    ``kernel_mode`` (resolved or ``"auto"``) routes the conv blocks:
    ``"xla"`` (the default, bit-identical to what this function always
    did) keeps the plain im2col einsum; the fused modes run them through
    the Pallas conv kernel.  The engine threads its resolved mode here.
    """
    from repro.kernels import dispatch as _kd
    mode = _kd.resolve_kernel_mode(kernel_mode)
    if mode == "xla":
        return _apply(params, images, _conv3x3_same_im2col)
    feats = _features_fused(params, images, mode)
    return feats @ params["dense"] + params["b3"]


def _loss(apply, params, images, labels):
    logits = apply(params, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def cnn_loss(params: dict, images: jnp.ndarray, labels: jnp.ndarray
             ) -> jnp.ndarray:
    return _loss(cnn_apply, params, images, labels)


def cnn_loss_fast(params: dict, images: jnp.ndarray, labels: jnp.ndarray,
                  kernel_mode: str = "xla") -> jnp.ndarray:
    def apply(p, im):
        return cnn_apply_fast(p, im, kernel_mode=kernel_mode)
    return _loss(apply, params, images, labels)


def _accuracy(apply, params, images, labels):
    return jnp.mean((jnp.argmax(apply(params, images), -1) == labels)
                    .astype(jnp.float32))


def cnn_accuracy(params: dict, images: jnp.ndarray, labels: jnp.ndarray
                 ) -> jnp.ndarray:
    return _accuracy(cnn_apply, params, images, labels)


def cnn_accuracy_fast(params: dict, images: jnp.ndarray, labels: jnp.ndarray,
                      kernel_mode: str = "xla") -> jnp.ndarray:
    """``cnn_accuracy`` on the im2col forward (the engine's eval path).

    Under a fused ``kernel_mode`` the whole eval runs kernel-routed: conv
    blocks through the fused conv kernel, then the classifier head as one
    logits → argmax → correct-count pass (``kernels.dispatch.eval_head``)
    — the logits buffer never materializes.  Count / #rows equals the
    mean-of-hits the XLA path computes (both exact in f32).
    """
    from repro.kernels import dispatch as _kd
    mode = _kd.resolve_kernel_mode(kernel_mode)
    if mode == "xla":
        return _accuracy(cnn_apply_fast, params, images, labels)
    feats = _features_fused(params, images, mode)
    count = _kd.eval_head(feats, params["dense"], params["b3"], labels,
                          mode=mode)
    return count.astype(jnp.float32) / labels.shape[0]
