"""GQA attention with RoPE, qk-norm, sliding-window, KV cache, cross-attn.

Three entry points per block:
  * ``attn_train``   — full-sequence causal (optionally windowed) attention.
  * ``attn_prefill`` — same as train but also returns the populated KV cache.
  * ``attn_decode``  — one query token against a cache, in-place cache update.

Caches are dicts {"k": [B, S, Hkv, Dh], "v": ..., plus ring metadata for
sliding windows}.  All math is einsum-based so the GSPMD partitioner shards
heads over the model axis; the Pallas flash kernel (kernels/flash_attention)
is swapped in by the launch layer on TPU via ``use_flash``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import norm_spec, rms_norm
from .spec import ParamSpec

NEG_INF = -2.0 ** 30  # large-negative that survives bf16

# Launch-layer hint (set by repro.launch.steps when the arch's head counts
# divide the mesh's model axis): NamedSharding P(None, None, "model", None)
# applied to q/k/v in the training paths.  With sequence-parallel residuals
# this is the Megatron SP->TP transition — attention runs head-local over
# the full sequence instead of re-gathering seq-sharded K/V inside every
# q-chunk iteration (measured: 216 gathers/step at dsv2 train).
HEAD_SPEC = None

# Fallback for archs whose head count does NOT divide the model axis
# (qwen3/minicpm3: 40 heads on 16): K/V cannot be head-sharded, and the
# chunked-q loop would re-gather seq-sharded K/V on every iteration.
# Setting this (a replicated NamedSharding) hoists ONE gather per layer in
# front of the loop instead (§Perf Q1).
KV_GATHER_SPEC = None


def _head_shard(*ts):
    if HEAD_SPEC is None:
        return ts if len(ts) > 1 else ts[0]
    out = tuple(jax.lax.with_sharding_constraint(t, HEAD_SPEC) for t in ts)
    return out if len(out) > 1 else out[0]


# ------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, Dh]; pos: [..., S] absolute positions."""
    freqs = rope_freqs(x.shape[-1], theta)                      # [Dh/2]
    ang = pos[..., None].astype(jnp.float32) * freqs            # [..., S, Dh/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ specs
def attn_specs(cfg: ArchConfig, stacked: Optional[int], cross: bool = False) -> dict:
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    out = {
        "wq": ParamSpec(pre_s + (d, h, dh), pre_a + ("embed", "heads", None)),
        "wk": ParamSpec(pre_s + (d, hkv, dh), pre_a + ("embed", "kv_heads", None)),
        "wv": ParamSpec(pre_s + (d, hkv, dh), pre_a + ("embed", "kv_heads", None)),
        "wo": ParamSpec(pre_s + (h, dh, d), pre_a + ("heads", None, "embed")),
        "norm": norm_spec(d, pre_a, pre_s),
    }
    if cfg.qk_norm:
        out["q_norm"] = norm_spec(dh, pre_a, pre_s)
        out["k_norm"] = norm_spec(dh, pre_a, pre_s)
    if cross:
        out["xattn_gate"] = ParamSpec(pre_s + (1,), pre_a + (None,), init="zeros")
    return out


# ------------------------------------------------------------------ masks
def causal_mask(s_q: int, s_kv: int, q_offset: int = 0,
                window: Optional[int] = None) -> jnp.ndarray:
    """[s_q, s_kv] additive mask; window = sliding-window size (None = full)."""
    qpos = jnp.arange(s_q)[:, None] + q_offset
    kpos = jnp.arange(s_kv)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF)


ATTN_Q_CHUNK = 512   # q-block size for the chunked softmax(QK^T)V path


def _sdpa_block(q, k, v, bias):
    """q: [B,Sq,H,Dh]; k/v: [B,Skv,Hkv,Dh] (GQA-expanded inside)."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    group = h // hkv
    q = q.reshape(b, sq, hkv, group, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(dh)
    logits = logits + bias  # bias broadcasts over [B?,H?,g?,q,k]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(v.dtype)


# Kernel backend switch (set by the launch layer on real TPUs): routes the
# full-sequence paths through kernels/flash_attention (pl.pallas_call).
# Off by default here — interpret mode on CPU is a Python loop.
USE_FLASH_KERNEL = False


def _sdpa(q, k, v, *, causal: bool, window=None, q_offset: int = 0,
          bias=None, chunk: int = ATTN_Q_CHUNK):
    """Memory-bounded attention: q is processed in remat'd chunks so neither
    the [Sq, Skv] mask nor the [B, H, Sq, Skv] logits ever materialize in
    full.  ``bias`` short-circuits chunking (decode-style precomputed masks).
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    if USE_FLASH_KERNEL and bias is None and sq > 1:
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    if bias is not None:
        return _sdpa_block(q, k, v, bias)
    if sq <= chunk:
        m = (causal_mask(sq, skv, q_offset=q_offset, window=window)
             if (causal or window) else jnp.zeros((), q.dtype))
        return _sdpa_block(q, k, v, m)

    if HEAD_SPEC is None and KV_GATHER_SPEC is not None:
        # gather K/V once per layer, not once per q-chunk iteration
        k = jax.lax.with_sharding_constraint(k, KV_GATHER_SPEC)
        v = jax.lax.with_sharding_constraint(v, KV_GATHER_SPEC)
    pad = (-sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // chunk
    qb = jnp.moveaxis(q.reshape(b, nq, chunk, h, dh), 1, 0)
    offs = q_offset + jnp.arange(nq) * chunk

    @jax.checkpoint
    def block(args):
        qc, off = args
        if causal or window:
            # mask rows shifted by the block's dynamic offset
            qpos = jnp.arange(chunk)[:, None] + off
            kpos = jnp.arange(skv)[None, :]
            ok = kpos <= qpos if causal else jnp.ones((chunk, skv), bool)
            if window is not None:
                ok &= kpos > qpos - window
            m = jnp.where(ok, 0.0, NEG_INF)
        else:
            m = jnp.zeros((), jnp.float32)
        return _sdpa_block(qc, k, v, m)

    out = jax.lax.map(block, (qb, offs))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * chunk, h, dh)
    return out[:, :sq]


def _qkv(p: dict, x: jnp.ndarray, cfg: ArchConfig, kv_x: Optional[jnp.ndarray] = None):
    src = x if kv_x is None else kv_x
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"])
    k = jnp.einsum("...d,dhk->...hk", src, p["wk"])
    v = jnp.einsum("...d,dhk->...hk", src, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _proj_out(p: dict, attn: jnp.ndarray, x: jnp.ndarray, cross: bool) -> jnp.ndarray:
    out = jnp.einsum("...hk,hkd->...d", attn, p["wo"])
    if cross:
        out = out * jnp.tanh(p["xattn_gate"]).astype(out.dtype)
    return x + out


# ------------------------------------------------------------- full-seq ops
def attn_train(p: dict, x: jnp.ndarray, cfg: ArchConfig, *, causal: bool = True,
               pos_offset: int = 0) -> jnp.ndarray:
    """Self-attention over a full sequence. x: [B, S, D]."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg)
    s = x.shape[-2]
    pos = jnp.arange(s) + pos_offset
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q, k, v = _head_shard(q, k, v)
    out = _sdpa(q, k, v, causal=causal,
                window=cfg.sliding_window if causal else None)
    return _proj_out(p, out, x, cross=False)


def xattn_train(p: dict, x: jnp.ndarray, memory: jnp.ndarray, cfg: ArchConfig
                ) -> jnp.ndarray:
    """Cross-attention to ``memory`` [B, S_mem, D] (no RoPE on memory)."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, kv_x=memory)
    out = _sdpa(q, k, v, causal=False)
    return _proj_out(p, out, x, cross="xattn_gate" in p)


# ------------------------------------------------------------------- cache
def init_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                    stacked: Optional[int], dtype=jnp.bfloat16) -> dict:
    """KV cache spec. Sliding-window archs cache only the window (ring)."""
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    return {
        "k": ParamSpec(pre_s + (batch, length, hkv, dh),
                       pre_a + ("act_batch", "kv_seq", "kv_heads", None), dtype, "zeros"),
        "v": ParamSpec(pre_s + (batch, length, hkv, dh),
                       pre_a + ("act_batch", "kv_seq", "kv_heads", None), dtype, "zeros"),
    }


def attn_prefill(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict
                 ) -> tuple[jnp.ndarray, dict]:
    """Full-sequence attention that also fills the cache (keys post-RoPE)."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg)
    s = x.shape[-2]
    pos = jnp.arange(s)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    out = _proj_out(p, _sdpa(q, k, v, causal=True,
                             window=cfg.sliding_window), x, cross=False)
    clen = cache["k"].shape[-3]
    keep = min(s, clen)
    # ring placement: position p lives at slot p % clen (no-op when clen >= s)
    slots = (jnp.arange(s - keep, s) % clen)
    new_cache = {
        "k": cache["k"].at[..., slots, :, :].set(
            k[..., -keep:, :, :].astype(cache["k"].dtype)),
        "v": cache["v"].at[..., slots, :, :].set(
            v[..., -keep:, :, :].astype(cache["v"].dtype)),
    }
    return out, new_cache


def attn_decode(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict,
                pos: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
    """One-token decode. x: [B, 1, D]; pos: scalar current position.

    Sliding-window caches are rings indexed by pos % window; full caches
    write at pos.  Key invariant: cached keys already carry RoPE.
    """
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k = apply_rope(k, pos[None], cfg.rope_theta)
    clen = cache["k"].shape[-3]
    slot = (pos % clen) if cfg.sliding_window else pos
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=-3)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=-3)
    kpos_abs = jnp.arange(clen)
    if cfg.sliding_window:
        # ring: entry i holds the latest position congruent to i mod clen
        kpos_abs = jnp.where(kpos_abs <= slot, pos - slot + kpos_abs,
                             pos - slot - clen + kpos_abs)
    valid = (kpos_abs >= 0) & (kpos_abs <= pos)
    if cfg.sliding_window:
        valid &= kpos_abs > pos - cfg.sliding_window
    bias = jnp.where(valid, 0.0, NEG_INF)[None, :]  # [1(sq), clen]
    out = _proj_out(p, _sdpa(q, ck, cv, causal=False, bias=bias), x,
                    cross=False)
    return out, {"k": ck, "v": cv}


def xattn_decode(p: dict, x: jnp.ndarray, memory: jnp.ndarray, cfg: ArchConfig
                 ) -> jnp.ndarray:
    """Cross-attention for decode — memory is static, no cache mutation."""
    return xattn_train(p, x, memory, cfg)
