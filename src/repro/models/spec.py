"""Parameter/activation specs with logical sharding axes.

Every parameter is described by a ``ParamSpec`` carrying *logical* axis names
(e.g. ``("layers", "embed", "mlp")``).  The launch layer maps logical names to
mesh axes per runtime (train Layout A / serve Layout B / FSDP Layout C) — see
``repro.launch.sharding``.  Models never mention mesh axes directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    dtype: Any = jnp.float32
    init: str = "normal"              # normal | zeros | ones | scaled

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_from_specs(specs: PyTree, key: jax.Array, param_dtype=None) -> PyTree:
    """Materialize parameters from a spec pytree (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for spec, k in zip(leaves, keys):
        dtype = param_dtype or spec.dtype
        if spec.init == "zeros":
            v = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            v = jnp.ones(spec.shape, dtype)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            scale = 1.0 / np.sqrt(max(fan_in, 1))
            v = (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dtype)
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_from_specs(specs: PyTree, param_dtype=None) -> PyTree:
    """ShapeDtypeStruct stand-ins (for .lower() without allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, param_dtype or s.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def logical_axes(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def count_params(specs: PyTree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)))
