"""Model zoo shared by the FL layer (local training) and serving layer."""
from .config import (ArchConfig, EncoderConfig, InputShape, MLAConfig,
                     MoEConfig, RGLRUConfig, SSMConfig, INPUT_SHAPES,
                     TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
from .spec import (ParamSpec, init_from_specs, abstract_from_specs,
                   logical_axes, count_params)
from .transformer import (param_specs, cache_specs, forward_train, loss_fn,
                          prefill, decode_step, encode)
from .cnn import (cnn_specs, cnn_apply, cnn_apply_fast, cnn_loss,
                  cnn_loss_fast, cnn_accuracy, cnn_accuracy_fast)

__all__ = [
    "ArchConfig", "EncoderConfig", "InputShape", "MLAConfig", "MoEConfig",
    "RGLRUConfig", "SSMConfig", "INPUT_SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K",
    "ParamSpec", "init_from_specs", "abstract_from_specs", "logical_axes",
    "count_params",
    "param_specs", "cache_specs", "forward_train", "loss_fn", "prefill",
    "decode_step", "encode",
    "cnn_specs", "cnn_apply", "cnn_apply_fast", "cnn_loss", "cnn_loss_fast",
    "cnn_accuracy", "cnn_accuracy_fast",
]
