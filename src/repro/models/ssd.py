"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Block: in_proj -> [z | x | B | C | dt], causal conv over (x,B,C), SSD core,
gated RMSNorm, out_proj.  The SSD core uses the paper's chunked algorithm:
quadratic attention-like intra-chunk term + linear inter-chunk state
recurrence — this is the "duality" and is the TPU-friendly formulation
(dense matmuls inside chunks feed the MXU; the cross-chunk scan is tiny).

Decode is the O(1) recurrent form on a per-head state [H, P, N].
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import norm_spec, rms_norm
from .spec import ParamSpec


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.d_state, s.head_dim


def ssd_specs(cfg: ArchConfig, stacked: Optional[int]) -> dict:
    s = cfg.ssm
    d_inner, nh, n, p_dim = _dims(cfg)
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    d = cfg.d_model
    conv_dim = d_inner + 2 * n
    return {
        "w_in": ParamSpec(pre_s + (d, 2 * d_inner + 2 * n + nh),
                          pre_a + ("embed", "mlp")),
        "conv_w": ParamSpec(pre_s + (s.d_conv, conv_dim), pre_a + (None, "mlp")),
        "a_log": ParamSpec(pre_s + (nh,), pre_a + (None,), init="ones"),
        "dt_bias": ParamSpec(pre_s + (nh,), pre_a + (None,), init="zeros"),
        "d_skip": ParamSpec(pre_s + (nh,), pre_a + (None,), init="ones"),
        "out_norm": norm_spec(d_inner, pre_a, pre_s),
        "w_out": ParamSpec(pre_s + (d_inner, d), pre_a + ("mlp", "embed")),
        "norm": norm_spec(d, pre_a, pre_s),
    }


def _split_proj(p, h, cfg):
    d_inner, nh, n, _ = _dims(cfg)
    zxbcdt = jnp.einsum("...d,de->...e", h, p["w_in"])
    return jnp.split(zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n,
                              2 * d_inner + 2 * n], axis=-1)


def _conv(p, u, state=None):
    k = p["conv_w"].shape[0]
    pad = state if state is not None else jnp.zeros(
        u.shape[:-2] + (k - 1, u.shape[-1]), u.dtype)
    full = jnp.concatenate([pad, u], axis=-2)
    out = sum(full[..., i:i + u.shape[-2], :] * p["conv_w"][i] for i in range(k))
    return jax.nn.silu(out), full[..., -(k - 1):, :]


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: [..., q, h] -> [..., h, q, q] with S[i,j] = sum_{j<k<=i} a_k (lower-tri)."""
    q = a.shape[-2]
    a_t = jnp.moveaxis(a, -1, -2)                      # [..., h, q]
    cum = jnp.cumsum(a_t, axis=-1)                     # [..., h, q]
    diff = cum[..., :, None] - cum[..., None, :]       # [..., h, q, q]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_core(x: jnp.ndarray, a_log: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
             chunk: int, h0: Optional[jnp.ndarray] = None
             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. x:[b,s,h,p] (dt-scaled), a_log:[b,s,h] (negative),
    B,C:[b,s,n] shared across heads. Returns (y, final_state [b,h,p,n])."""
    b, s, nh, pd = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, nh, pd)
    ac = a_log.reshape(b, nc, chunk, nh).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, n).astype(jnp.float32)
    xf = xc.astype(jnp.float32)

    # intra-chunk (quadratic, attention-like)
    L = jnp.exp(_segsum(ac))                                   # [b,c,h,q,q]
    y_diag = jnp.einsum("bcqn,bckn,bchqk,bckhp->bcqhp", Cc, Bc, L, xf)

    # per-chunk final states
    cum = jnp.cumsum(ac, axis=2)                               # [b,c,q,h]
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)            # [b,c,q,h]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_states, xf)

    # inter-chunk recurrence (associative scan over chunks)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # [b,c,h]
    if h0 is None:
        h0 = jnp.zeros((b, nh, pd, n), jnp.float32)

    def combine(c1, c2):
        d1, s1 = c1
        d2, s2 = c2
        return d1 * d2, s1 * d2[..., None, None] + s2

    d_s, h_s = jax.lax.associative_scan(combine, (chunk_decay, states), axis=1)
    h_s = h_s + d_s[..., None, None] * h0[:, None]             # include h0
    h_prev = jnp.concatenate([h0[:, None], h_s[:, :-1]], axis=1)  # [b,c,h,p,n]

    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, jnp.exp(cum), h_prev)
    y = (y_diag + y_off).reshape(b, nc * chunk, nh, pd)[:, :s]
    return y, h_s[:, -1]


def ssd_train(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    out, _ = _ssd_forward(p, x, cfg, conv_state=None, h0=None)
    return out


def ssd_cache_spec(cfg: ArchConfig, batch: int, stacked: Optional[int],
                   dtype=jnp.float32) -> dict:
    s = cfg.ssm
    d_inner, nh, n, pd = _dims(cfg)
    conv_dim = d_inner + 2 * n
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    return {
        "h": ParamSpec(pre_s + (batch, nh, pd, n),
                       pre_a + ("act_batch", None, None, None), dtype, "zeros"),
        "conv": ParamSpec(pre_s + (batch, s.d_conv - 1, conv_dim),
                          pre_a + ("act_batch", None, None), dtype, "zeros"),
    }


def _ssd_forward(p, x, cfg, conv_state, h0):
    s_cfg = cfg.ssm
    d_inner, nh, n, pd = _dims(cfg)
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    z, xs, B, C, dt = _split_proj(p, h, cfg)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)
    conv_out, new_conv = _conv(p, conv_in, conv_state)
    xs, B, C = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [b,s,h]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                  # [h] negative
    a_log = dt * a                                                # [b,s,h]
    xh = xs.reshape(xs.shape[:-1] + (nh, pd))
    x_dt = xh.astype(jnp.float32) * dt[..., None]
    y, h_final = ssd_core(x_dt, a_log, B, C, s_cfg.chunk, h0)
    y = y + p["d_skip"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(y.shape[:-2] + (d_inner,)).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = x + jnp.einsum("...e,ed->...d", y, p["w_out"])
    return out, {"h": h_final, "conv": new_conv}


def ssd_prefill(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict
                ) -> tuple[jnp.ndarray, dict]:
    out, new_cache = _ssd_forward(p, x, cfg, conv_state=None, h0=None)
    return out, {"h": new_cache["h"].astype(cache["h"].dtype),
                 "conv": new_cache["conv"].astype(cache["conv"].dtype)}


def ssd_decode(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict
               ) -> tuple[jnp.ndarray, dict]:
    """One-step recurrence. x: [B,1,D]; state h: [B,H,P,N]."""
    d_inner, nh, n, pd = _dims(cfg)
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    z, xs, B, C, dt = _split_proj(p, h, cfg)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)
    conv_out, new_conv = _conv(p, conv_in, cache["conv"].astype(conv_in.dtype))
    xs, B, C = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt[..., 0, :].astype(jnp.float32) + p["dt_bias"])  # [b,h]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                      # [b,h]
    xh = xs[..., 0, :].reshape(x.shape[0], nh, pd).astype(jnp.float32)
    Bf = B[..., 0, :].astype(jnp.float32)                        # [b,n]
    Cf = C[..., 0, :].astype(jnp.float32)
    h_new = decay[..., None, None] * cache["h"].astype(jnp.float32) \
        + jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bf)
    y = jnp.einsum("bn,bhpn->bhp", Cf, h_new) + p["d_skip"][:, None] * xh
    y = y.reshape(x.shape[0], 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = x + jnp.einsum("...e,ed->...d", y, p["w_out"])
    return out, {"h": h_new.astype(cache["h"].dtype),
                 "conv": new_conv.astype(cache["conv"].dtype)}
