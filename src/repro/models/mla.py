"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

KV is compressed into a latent ``c_kv`` of rank ``kv_lora_rank`` plus a
shared (across heads) RoPE key of ``qk_rope_head_dim`` — the decode cache
stores only ``[B, S, kv_lora + rope]`` instead of ``[B, S, Hkv, Dh]``.

Decode uses the *matrix-absorption* trick: q_nope is projected into latent
space (absorbing W_uk) so attention logits and value mixing run directly on
the compressed cache — the per-token expansion of K/V never materializes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .attention import NEG_INF, _head_shard, _sdpa, apply_rope
from .config import ArchConfig
from .layers import norm_spec, rms_norm
from .spec import ParamSpec


def mla_specs(cfg: ArchConfig, stacked: Optional[int]) -> dict:
    m = cfg.mla
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    out = {
        "w_dkv": ParamSpec(pre_s + (d, m.kv_lora_rank), pre_a + ("embed", None)),
        "kv_norm": norm_spec(m.kv_lora_rank, pre_a, pre_s),
        "w_kr": ParamSpec(pre_s + (d, m.qk_rope_head_dim), pre_a + ("embed", None)),
        "w_uk": ParamSpec(pre_s + (m.kv_lora_rank, h, m.qk_nope_head_dim),
                          pre_a + (None, "heads", None)),
        "w_uv": ParamSpec(pre_s + (m.kv_lora_rank, h, m.v_head_dim),
                          pre_a + (None, "heads", None)),
        "wo": ParamSpec(pre_s + (h, m.v_head_dim, d), pre_a + ("heads", None, "embed")),
        "norm": norm_spec(d, pre_a, pre_s),
    }
    if m.q_lora_rank:
        out["w_dq"] = ParamSpec(pre_s + (d, m.q_lora_rank), pre_a + ("embed", None))
        out["q_norm"] = norm_spec(m.q_lora_rank, pre_a, pre_s)
        out["w_uq"] = ParamSpec(pre_s + (m.q_lora_rank, h, qk),
                                pre_a + (None, "heads", None))
    else:
        out["wq"] = ParamSpec(pre_s + (d, h, qk), pre_a + ("embed", "heads", None))
    return out


def _q_proj(p: dict, h: jnp.ndarray, cfg: ArchConfig):
    m = cfg.mla
    if m.q_lora_rank:
        ql = rms_norm(jnp.einsum("...d,dr->...r", h, p["w_dq"]), p["q_norm"],
                      cfg.norm_eps)
        q = jnp.einsum("...r,rhk->...hk", ql, p["w_uq"])
    else:
        q = jnp.einsum("...d,dhk->...hk", h, p["wq"])
    return jnp.split(q, [m.qk_nope_head_dim], axis=-1)  # (q_nope, q_rope)


def mla_train(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Full-sequence causal MLA. x: [B, S, D]."""
    m = cfg.mla
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q_nope, q_rope = _q_proj(p, h, cfg)
    c_kv = rms_norm(jnp.einsum("...d,dr->...r", h, p["w_dkv"]), p["kv_norm"],
                    cfg.norm_eps)
    k_rope = jnp.einsum("...d,dr->...r", h, p["w_kr"])        # [B,S,rope]
    s = x.shape[-2]
    pos = jnp.arange(s)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    k_rope = apply_rope(k_rope[..., None, :], pos, cfg.rope_theta)[..., 0, :]
    k_nope = jnp.einsum("...sr,rhk->...shk", c_kv, p["w_uk"])  # [B,S,H,nope]
    v = jnp.einsum("...sr,rhk->...shk", c_kv, p["w_uv"])
    # expand to per-head K (nope || rope) and reuse the chunked SDPA — the
    # [S, S] logits never materialize in full (see attention._sdpa)
    nh = k_nope.shape[-2]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[..., None, :],
                                  k_nope.shape[:-1] + (m.qk_rope_head_dim,))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    pad = m.qk_nope_head_dim + m.qk_rope_head_dim - m.v_head_dim
    v_pad = jnp.pad(v, ((0, 0),) * (v.ndim - 1) + ((0, pad),)) if pad else v
    q_full, k_full, v_pad = _head_shard(q_full, k_full, v_pad)
    attn = _sdpa(q_full, k_full, v_pad, causal=True)
    attn = attn[..., :m.v_head_dim] if pad else attn
    out = jnp.einsum("...hk,hkd->...d", attn.astype(x.dtype), p["wo"])
    return x + out


def mla_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                   stacked: Optional[int], dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    return {
        "c_kv": ParamSpec(pre_s + (batch, max_len, m.kv_lora_rank),
                          pre_a + ("act_batch", "kv_seq", None), dtype, "zeros"),
        "k_rope": ParamSpec(pre_s + (batch, max_len, m.qk_rope_head_dim),
                            pre_a + ("act_batch", "kv_seq", None), dtype, "zeros"),
    }


def mla_prefill(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict
                ) -> tuple[jnp.ndarray, dict]:
    m = cfg.mla
    out = mla_train(p, x, cfg)
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    c_kv = rms_norm(jnp.einsum("...d,dr->...r", h, p["w_dkv"]), p["kv_norm"],
                    cfg.norm_eps)
    k_rope = jnp.einsum("...d,dr->...r", h, p["w_kr"])
    pos = jnp.arange(x.shape[-2])
    k_rope = apply_rope(k_rope[..., None, :], pos, cfg.rope_theta)[..., 0, :]
    s = x.shape[-2]
    keep = min(s, cache["c_kv"].shape[-2])
    return out, {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv[..., -keep:, :].astype(cache["c_kv"].dtype),
            0, axis=-2),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[..., -keep:, :].astype(cache["k_rope"].dtype),
            0, axis=-2)}


def mla_decode(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict,
               pos: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
    """Absorbed one-token decode on the compressed cache. x: [B, 1, D]."""
    m = cfg.mla
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q_nope, q_rope = _q_proj(p, h, cfg)                        # [B,1,H,*]
    q_rope = apply_rope(q_rope, pos[None], cfg.rope_theta)
    c_new = rms_norm(jnp.einsum("...d,dr->...r", h, p["w_dkv"]), p["kv_norm"],
                     cfg.norm_eps)
    kr_new = jnp.einsum("...d,dr->...r", h, p["w_kr"])
    kr_new = apply_rope(kr_new[..., None, :], pos[None], cfg.rope_theta)[..., 0, :]
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=-2)
    ckr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=-2)
    # absorb W_uk into q: [B,1,H,nope] x [r,H,nope] -> [B,1,H,r]
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat, ck.astype(jnp.float32))
              + jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(jnp.float32),
                           ckr.astype(jnp.float32))) * scale
    clen = ck.shape[-2]
    valid = jnp.arange(clen) <= pos
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # value mixing in latent space, then expand through W_uv
    lat = jnp.einsum("bhqs,bsr->bqhr", probs, ck.astype(jnp.float32))
    attn = jnp.einsum("bqhr,rhk->bqhk", lat, p["w_uv"].astype(jnp.float32))
    out = jnp.einsum("...hk,hkd->...d", attn.astype(x.dtype), p["wo"])
    return x + out, {"c_kv": ck, "k_rope": ckr}
