"""Mixture-of-Experts MLP with top-k routing (Grok-1, DeepSeek-V2-Lite).

TPU-native capacity-based dispatch (Shazeer-style einsum): tokens are
scattered to ``[E, capacity, D]`` buffers with a one-hot dispatch tensor, run
through a batched expert FFN (experts shardable over the model axis →
expert parallelism), and combined back with router weights.  Overflowing
tokens are dropped by the router (standard capacity semantics); the shared
experts (DeepSeek) are dense SwiGLU applied to every token.

Returns the load-balance auxiliary loss (Switch-style) alongside the output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import norm_spec, rms_norm
from .spec import ParamSpec


def moe_specs(cfg: ArchConfig, stacked: Optional[int]) -> dict:
    m = cfg.moe
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    d = cfg.d_model
    fe = m.d_expert or cfg.d_ff
    out = {
        "router": ParamSpec(pre_s + (d, m.n_experts), pre_a + ("embed", None)),
        "gate": ParamSpec(pre_s + (m.n_experts, d, fe),
                          pre_a + ("experts", "embed", "mlp")),
        "up": ParamSpec(pre_s + (m.n_experts, d, fe),
                        pre_a + ("experts", "embed", "mlp")),
        "down": ParamSpec(pre_s + (m.n_experts, fe, d),
                          pre_a + ("experts", "mlp", "embed")),
        "norm": norm_spec(d, pre_a, pre_s),
    }
    if m.n_shared:
        out["sh_gate"] = ParamSpec(pre_s + (d, fe * m.n_shared),
                                   pre_a + ("embed", "mlp"))
        out["sh_up"] = ParamSpec(pre_s + (d, fe * m.n_shared),
                                 pre_a + ("embed", "mlp"))
        out["sh_down"] = ParamSpec(pre_s + (fe * m.n_shared, d),
                                   pre_a + ("mlp", "embed"))
    return out


def _capacity(n_tokens: int, m) -> int:
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(cap, m.top_k)


MOE_BLOCK = 256   # token-block size for dispatch (aligns with the 16-way
                  # sequence sharding of 4k training activations)

# Launch-layer hint (set by repro.launch.steps when the mesh's model axis
# divides n_experts): a pair (local_spec, ep_spec) of NamedShardings for the
# dispatched [b, ns, E, cap, d] buffers — token-block-sharded (natural
# einsum output) and expert-sharded.  Applying them back-to-back pins the
# GShard all-to-all: constraining the einsum output directly lets GSPMD
# propagate the expert sharding INTO the einsum, where its fallback is a
# full activation all-gather (measured: 27×8 GiB per step at dsv2 train).
EXPERT_PARALLEL_SPEC = None


def moe_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y, aux_loss).

    Block-wise one-hot einsum dispatch (GShard / Mesh-TF style): tokens are
    processed in [B, ns, block] groups with per-block expert capacity, and
    dispatch/combine are dense einsums with tiny one-hot factors.  This is
    the TPU-native formulation — a scatter/gather dispatch has
    data-dependent indices GSPMD cannot partition, so it replicates the
    [T·k, D] update tensor across the mesh (measured: 2.6 TB of
    all-reduce per step at deepseek-v2 train_4k).  Blocks stay aligned
    with the sequence sharding, so everything partitions locally.
    """
    m = cfg.moe
    b, s, d = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    blk = MOE_BLOCK if s % MOE_BLOCK == 0 else s
    ns = s // blk
    cap = _capacity(blk, m)
    hb = h.reshape(b, ns, blk, d)

    logits = jnp.einsum("bntd,de->bnte", hb.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # [b,ns,blk,E]
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)     # [b,ns,blk,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, k) inside its expert's per-block buffer
    oh = jax.nn.one_hot(expert_idx, m.n_experts, dtype=jnp.int32)
    oh_flat = oh.reshape(b, ns, blk * m.top_k, m.n_experts)
    pos_flat = jnp.cumsum(oh_flat, axis=2) - oh_flat
    pos_in_e = pos_flat.reshape(b, ns, blk, m.top_k, m.n_experts)
    pos = jnp.sum(pos_in_e * oh, axis=-1)                     # [b,ns,blk,k]
    keep = (pos < cap).astype(jnp.float32)

    pos_oh = jax.nn.one_hot(jnp.minimum(pos, cap - 1), cap)   # [b,ns,blk,k,C]
    send = oh.astype(jnp.float32) * keep[..., None]           # [b,ns,blk,k,E]
    # dispatch (0/1) and combine (gate-weighted) tensors [b,ns,blk,E,C]
    disp = jnp.einsum("bntke,bntkc->bntec", send, pos_oh)
    comb = jnp.einsum("bntke,bntkc->bntec", send * gate_vals[..., None],
                      pos_oh)

    xin = jnp.einsum("bntec,bntd->bnecd", disp.astype(h.dtype), hb)
    if EXPERT_PARALLEL_SPEC is not None:
        local_spec, ep_spec = EXPERT_PARALLEL_SPEC
        xin = jax.lax.with_sharding_constraint(xin, local_spec)
        xin = jax.lax.with_sharding_constraint(xin, ep_spec)   # all-to-all
    g = jnp.einsum("bnecd,edf->bnecf", xin, p["gate"])
    u = jnp.einsum("bnecd,edf->bnecf", xin, p["up"])
    eout = jnp.einsum("bnecf,efd->bnecd", jax.nn.silu(g) * u, p["down"])
    if EXPERT_PARALLEL_SPEC is not None:
        eout = jax.lax.with_sharding_constraint(eout, ep_spec)
        eout = jax.lax.with_sharding_constraint(eout, local_spec)  # a2a back
    y = jnp.einsum("bntec,bnecd->bntd", comb.astype(h.dtype), eout)
    y = y.reshape(b, s, d)

    if m.n_shared:
        flat = h
        sg = jnp.einsum("bsd,df->bsf", flat, p["sh_gate"])
        su = jnp.einsum("bsd,df->bsf", flat, p["sh_up"])
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(sg) * su,
                           p["sh_down"])

    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    frac_tokens = jnp.mean(jnp.sum(oh, axis=-2).astype(jnp.float32),
                           axis=(0, 1, 2))
    frac_prob = jnp.mean(probs, axis=(0, 1, 2))
    aux = m.n_experts * jnp.sum(frac_tokens * frac_prob) * m.router_aux_weight

    return x + y, aux
