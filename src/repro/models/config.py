"""Architecture + workload configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""
    q_lora_rank: Optional[int]     # None = direct q projection
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0              # shared (always-on) experts
    d_expert: Optional[int] = None  # per-expert ffn width (default = d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance auxiliary loss


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma recurrent block."""
    lru_width: Optional[int] = None   # default = d_model
    d_conv: int = 4
    c_constant: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Second (encoder) stack for enc-dec architectures."""
    n_layers: int
    n_frames: int = 1500            # stubbed modality-frontend output length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # repeating block pattern, e.g. ("attn","mlp") per layer family:
    #   dense: unit = ("attn",); hybrid rg: unit = ("rec","rec","attn")
    #   vlm:   unit = ("attn","attn","attn","attn","xattn")
    block_pattern: tuple[str, ...] = ("attn",)
    tail_pattern: tuple[str, ...] = ()   # non-repeating trailing layers
    encoder: Optional[EncoderConfig] = None
    n_image_tokens: int = 1600       # vlm stub: patch-embedding count
    source: str = ""                 # citation
    # ---- distribution knobs ----
    clients_per_pod: int = 16        # FL client groups per pod (Layout A) or 1 (Layout C)
    param_dtype: str = "bfloat16"
    # long-context support: archs with sub-quadratic paths run long_500k
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_units(self) -> int:
        if not self.block_pattern:
            return 0
        n = (self.n_layers - len(self.tail_pattern)) // len(self.block_pattern)
        assert n * len(self.block_pattern) + len(self.tail_pattern) == self.n_layers, \
            (self.name, self.n_layers, self.block_pattern, self.tail_pattern)
        return n

    @property
    def jnp_param_dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.param_dtype]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str      # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")
INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
