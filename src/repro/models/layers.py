"""Shared layers: norms, MLPs, embeddings — functional, spec-driven."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .spec import ParamSpec


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def norm_spec(d: int, prefix_axes: tuple = (), prefix_shape: tuple = ()) -> ParamSpec:
    return ParamSpec(prefix_shape + (d,), prefix_axes + (None,), init="ones")


# ----------------------------------------------------------------- dense mlp
def mlp_specs(cfg: ArchConfig, stacked: Optional[int]) -> dict:
    """SwiGLU MLP: gate/up [d_model, d_ff], down [d_ff, d_model]."""
    pre_s = (stacked,) if stacked else ()
    pre_a = ("layers",) if stacked else ()
    d, f = cfg.d_model, cfg.d_ff
    return {
        "gate": ParamSpec(pre_s + (d, f), pre_a + ("embed", "mlp")),
        "up": ParamSpec(pre_s + (d, f), pre_a + ("embed", "mlp")),
        "down": ParamSpec(pre_s + (f, d), pre_a + ("mlp", "embed")),
        "norm": norm_spec(d, pre_a, pre_s),
    }


def mlp_apply(p: dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    h = rms_norm(x, p["norm"], eps)
    g = jnp.einsum("...d,df->...f", h, p["gate"])
    u = jnp.einsum("...d,df->...f", h, p["up"])
    out = jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, p["down"])
    return x + out


# ---------------------------------------------------------------- embeddings
def embed_specs(cfg: ArchConfig) -> dict:
    out = {"tok": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    out["final_norm"] = norm_spec(cfg.d_model)
    return out


def embed_apply(p: dict, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return p["tok"].astype(compute_dtype)[tokens]


def unembed_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    h = rms_norm(x, p["final_norm"], cfg.norm_eps)
    head = p["tok"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("...d,dv->...v", h, head.astype(x.dtype))
