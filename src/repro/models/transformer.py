"""Model assembly: scan-over-units decoder stacks for every arch family.

A model is a repeating ``block_pattern`` of layer kinds scanned ``n_units``
times (keeping HLO size flat in depth), plus optional non-repeating ``tail``
layers, an optional encoder stack (audio enc-dec), and optional cross-attn
memory (VLM image embeddings / encoder output).

Layer kinds:
  attn      GQA self-attention + SwiGLU MLP
  mla       multi-head latent attention + SwiGLU MLP
  attn_moe  GQA self-attention + MoE MLP
  mla_moe   MLA + MoE MLP
  rec       RG-LRU recurrent block + SwiGLU MLP
  ssd       Mamba-2 SSD block (no separate MLP)
  xattn     cross-attention (gated) + SwiGLU MLP

Three execution modes: ``train`` (full seq, causal), ``prefill`` (train +
cache fill), ``decode`` (one token against a cache).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as att
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rg_mod
from . import ssd as ssd_mod
from .config import ArchConfig
from .layers import embed_apply, embed_specs, mlp_apply, mlp_specs, unembed_apply
from .spec import ParamSpec  # noqa: F401

PyTree = Any

MIXER = {"attn": "attn", "attn_moe": "attn", "mla": "mla", "mla_moe": "mla",
         "rec": "rec", "ssd": "ssd", "xattn": "xattn", "enc_attn": "enc_attn"}
FFN = {"attn": "mlp", "attn_moe": "moe", "mla": "mlp", "mla_moe": "moe",
       "rec": "mlp", "ssd": None, "xattn": "mlp", "enc_attn": "mlp"}


# ------------------------------------------------------------------- specs
def _layer_specs(kind: str, cfg: ArchConfig, stacked: Optional[int]) -> dict:
    mixer, ffn = MIXER[kind], FFN[kind]
    out = {}
    if mixer in ("attn", "enc_attn"):
        out["mixer"] = att.attn_specs(cfg, stacked)
    elif mixer == "mla":
        out["mixer"] = mla_mod.mla_specs(cfg, stacked)
    elif mixer == "rec":
        out["mixer"] = rg_mod.rglru_specs(cfg, stacked)
    elif mixer == "ssd":
        out["mixer"] = ssd_mod.ssd_specs(cfg, stacked)
    elif mixer == "xattn":
        out["mixer"] = att.attn_specs(cfg, stacked, cross=True)
    if ffn == "mlp":
        out["ffn"] = mlp_specs(cfg, stacked)
    elif ffn == "moe":
        out["ffn"] = moe_mod.moe_specs(cfg, stacked)
    return out


def param_specs(cfg: ArchConfig) -> dict:
    specs: dict = {"embed": embed_specs(cfg)}
    if cfg.n_units:
        specs["unit"] = {str(i): _layer_specs(k, cfg, cfg.n_units)
                         for i, k in enumerate(cfg.block_pattern)}
    if cfg.tail_pattern:
        specs["tail"] = {str(i): _layer_specs(k, cfg, None)
                         for i, k in enumerate(cfg.tail_pattern)}
    if cfg.encoder:
        specs["encoder"] = {
            "unit": {"0": _layer_specs("enc_attn", cfg, cfg.encoder.n_layers)}}
    return specs


def _layer_cache_spec(kind: str, cfg: ArchConfig, batch: int, max_len: int,
                      stacked: Optional[int], dtype) -> Optional[dict]:
    mixer = MIXER[kind]
    if mixer == "attn":
        return att.init_cache_spec(cfg, batch, max_len, stacked, dtype)
    if mixer == "mla":
        return mla_mod.mla_cache_spec(cfg, batch, max_len, stacked, dtype)
    if mixer == "rec":
        return rg_mod.rglru_cache_spec(cfg, batch, stacked)
    if mixer == "ssd":
        return ssd_mod.ssd_cache_spec(cfg, batch, stacked)
    return None  # xattn: static memory, no cache


def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    """KV/state cache specs. Recurrent states stay f32 (numerically load-
    bearing); KV caches use ``dtype`` (bf16 in production, f32 in tests)."""
    out: dict = {}
    if cfg.n_units:
        out["unit"] = {
            str(i): cs for i, k in enumerate(cfg.block_pattern)
            if (cs := _layer_cache_spec(k, cfg, batch, max_len, cfg.n_units,
                                        dtype)) is not None}
    if cfg.tail_pattern:
        out["tail"] = {
            str(i): cs for i, k in enumerate(cfg.tail_pattern)
            if (cs := _layer_cache_spec(k, cfg, batch, max_len, None, dtype))
            is not None}
    return out


# ------------------------------------------------------------------- apply
def _apply_layer(kind: str, p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
                 mode: str, cache: Optional[dict], pos, memory, aux):
    """One layer in the given mode. Returns (x, new_cache, aux)."""
    mixer, ffn = MIXER[kind], FFN[kind]
    new_cache = None
    if mixer in ("attn", "enc_attn"):
        causal = mixer == "attn"
        if mode == "train" or mixer == "enc_attn":
            x = att.attn_train(p["mixer"], x, cfg, causal=causal)
        elif mode == "prefill":
            x, new_cache = att.attn_prefill(p["mixer"], x, cfg, cache)
        else:
            x, new_cache = att.attn_decode(p["mixer"], x, cfg, cache, pos)
    elif mixer == "mla":
        if mode == "train":
            x = mla_mod.mla_train(p["mixer"], x, cfg)
        elif mode == "prefill":
            x, new_cache = mla_mod.mla_prefill(p["mixer"], x, cfg, cache)
        else:
            x, new_cache = mla_mod.mla_decode(p["mixer"], x, cfg, cache, pos)
    elif mixer == "rec":
        if mode == "train":
            x = rg_mod.rglru_train(p["mixer"], x, cfg)
        elif mode == "prefill":
            x, new_cache = rg_mod.rglru_prefill(p["mixer"], x, cfg, cache)
        else:
            x, new_cache = rg_mod.rglru_decode(p["mixer"], x, cfg, cache)
    elif mixer == "ssd":
        if mode == "train":
            x = ssd_mod.ssd_train(p["mixer"], x, cfg)
        elif mode == "prefill":
            x, new_cache = ssd_mod.ssd_prefill(p["mixer"], x, cfg, cache)
        else:
            x, new_cache = ssd_mod.ssd_decode(p["mixer"], x, cfg, cache)
    elif mixer == "xattn":
        x = att.xattn_train(p["mixer"], x, memory, cfg)

    if ffn == "mlp":
        x = mlp_apply(p["ffn"], x, cfg.norm_eps)
    elif ffn == "moe":
        x, moe_aux = moe_mod.moe_apply(p["ffn"], x, cfg)
        aux = aux + moe_aux
    return x, new_cache, aux


def _run_stack(params: dict, x: jnp.ndarray, cfg: ArchConfig, pattern,
               *, mode: str, caches: Optional[dict], pos, memory,
               remat: bool, encoder: bool = False, act_spec=None):
    """Scan the repeating units, then the tail. Returns (x, new_caches, aux)."""
    aux0 = jnp.zeros((), jnp.float32)
    unit_params = params.get("unit")
    new_caches: dict = {}

    def body(carry, xs):
        x, aux = carry
        if act_spec is not None:
            # sequence-parallel residuals: the scan carry (the only
            # activation remat keeps alive per layer) is sharded over the
            # model axis instead of replicated within each TP group
            x = jax.lax.with_sharding_constraint(x, act_spec)
        up, uc = xs
        ncs = {}
        for i, kind in enumerate(pattern):
            c = uc.get(str(i)) if uc else None
            x, nc, aux = _apply_layer(kind, up[str(i)], x, cfg, mode=mode,
                                      cache=c, pos=pos, memory=memory, aux=aux)
            if act_spec is not None:
                # re-assert after every residual add: the partial-sum
                # attention/MLP outputs then lower to reduce-scatter
                # instead of all-reduce + local slice (§Perf A1)
                x = jax.lax.with_sharding_constraint(x, act_spec)
            if nc is not None:
                ncs[str(i)] = nc
        return (x, aux), ncs

    body_fn = jax.checkpoint(body) if remat else body
    if unit_params is not None:
        uc = (caches or {}).get("unit", {})
        (x, aux0), new_unit_caches = jax.lax.scan(
            body_fn, (x, aux0), (unit_params, uc))
        if new_unit_caches:
            new_caches["unit"] = new_unit_caches

    tail = params.get("tail")
    if tail is not None and not encoder:
        tcs = {}
        tc = (caches or {}).get("tail", {})
        for i, kind in enumerate(cfg.tail_pattern):
            c = tc.get(str(i))
            x, nc, aux0 = _apply_layer(kind, tail[str(i)], x, cfg, mode=mode,
                                       cache=c, pos=pos, memory=memory,
                                       aux=aux0)
            if nc is not None:
                tcs[str(i)] = nc
        if tcs:
            new_caches["tail"] = tcs
    return x, new_caches, aux0


def _encode(params: dict, memory_embeds: jnp.ndarray, cfg: ArchConfig,
            remat: bool) -> jnp.ndarray:
    """Run the encoder stack over stubbed frontend embeddings."""
    x, _, _ = _run_stack(params["encoder"], memory_embeds, cfg,
                         ("enc_attn",), mode="train", caches=None, pos=None,
                         memory=None, remat=remat, encoder=False)
    return x


def _memory(params: dict, cfg: ArchConfig, memory_embeds, remat: bool):
    if memory_embeds is None:
        return None
    if cfg.encoder:
        return _encode(params, memory_embeds, cfg, remat)
    return memory_embeds  # vlm: projector output fed directly


# ------------------------------------------------------------- public API
def forward_train(params: dict, tokens: jnp.ndarray, cfg: ArchConfig, *,
                  memory_embeds: Optional[jnp.ndarray] = None,
                  remat: bool = False, act_spec=None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B, S] -> (logits [B, S, V], aux loss)."""
    dt = cfg.jnp_param_dtype
    x = embed_apply(params["embed"], tokens, dt)
    mem = _memory(params, cfg, memory_embeds, remat)
    x, _, aux = _run_stack(params, x, cfg, cfg.block_pattern, mode="train",
                           caches=None, pos=None, memory=mem, remat=remat,
                           act_spec=act_spec)
    return unembed_apply(params["embed"], x, cfg), aux


LOSS_CHUNK = 512   # seq-chunked cross-entropy threshold/size


def _nll(params, x, labels, cfg):
    logits = unembed_apply(params["embed"], x, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def loss_fn(params: dict, tokens: jnp.ndarray, labels: jnp.ndarray,
            cfg: ArchConfig, *, memory_embeds=None, remat: bool = False,
            act_spec=None) -> jnp.ndarray:
    dt = cfg.jnp_param_dtype
    x = embed_apply(params["embed"], tokens, dt)
    mem = _memory(params, cfg, memory_embeds, remat)
    x, _, aux = _run_stack(params, x, cfg, cfg.block_pattern, mode="train",
                           caches=None, pos=None, memory=mem, remat=remat,
                           act_spec=act_spec)
    valid = (labels >= 0).astype(jnp.float32)
    b, s = labels.shape
    if s <= LOSS_CHUNK or s % LOSS_CHUNK:
        nll = _nll(params, x, labels, cfg)
    else:
        # chunked cross-entropy: the f32 [B, S, V] logits/logp never
        # materialize — each remat'd chunk computes its unembed + nll and
        # is recomputed in the backward pass
        nc = s // LOSS_CHUNK
        xc = jnp.moveaxis(x.reshape(b, nc, LOSS_CHUNK, -1), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, nc, LOSS_CHUNK), 1, 0)

        @jax.checkpoint
        def chunk(args):
            xi, li = args
            return _nll(params, xi, li, cfg)

        nll = jnp.moveaxis(jax.lax.map(chunk, (xc, lc)), 0, 1)
        nll = nll.reshape(b, s)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0) + aux


def prefill(params: dict, tokens: jnp.ndarray, cfg: ArchConfig, caches: dict,
            *, memory_embeds=None, remat: bool = False):
    """Returns (logits of last position [B, V], filled caches)."""
    dt = cfg.jnp_param_dtype
    x = embed_apply(params["embed"], tokens, dt)
    mem = _memory(params, cfg, memory_embeds, remat)
    x, new_caches, _ = _run_stack(params, x, cfg, cfg.block_pattern,
                                  mode="prefill", caches=caches, pos=None,
                                  memory=mem, remat=remat)
    logits = unembed_apply(params["embed"], x[..., -1:, :], cfg)
    return logits[..., 0, :], new_caches


def encode(params: dict, memory_embeds: jnp.ndarray, cfg: ArchConfig,
           remat: bool = False) -> jnp.ndarray:
    """Run the encoder once (enc-dec serving runs this at prefill time)."""
    return _memory(params, cfg, memory_embeds, remat)


def decode_step(params: dict, token: jnp.ndarray, pos: jnp.ndarray,
                cfg: ArchConfig, caches: dict, *, memory=None):
    """token [B, 1], pos scalar int32 -> (logits [B, V], new caches).

    ``memory`` is *pre-encoded* cross-attention memory (the encoder / vision
    projector runs once at prefill, not per decode step).
    """
    dt = cfg.jnp_param_dtype
    x = embed_apply(params["embed"], token, dt)
    x, new_caches, _ = _run_stack(params, x, cfg, cfg.block_pattern,
                                  mode="decode", caches=caches, pos=pos,
                                  memory=memory, remat=False)
    logits = unembed_apply(params["embed"], x, cfg)
    return logits[..., 0, :], new_caches
