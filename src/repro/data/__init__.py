from .synthetic import class_images, lm_tokens
from .partition import (by_class, class_pools, dirichlet, population_classes,
                        sample_class_batches)

__all__ = ["class_images", "lm_tokens", "by_class", "dirichlet",
           "population_classes", "class_pools", "sample_class_batches"]
