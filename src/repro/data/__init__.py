from .synthetic import class_images, lm_tokens
from .partition import by_class, dirichlet

__all__ = ["class_images", "lm_tokens", "by_class", "dirichlet"]
