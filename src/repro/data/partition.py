"""Non-IID partitioners (Sec. 6.1.1, 6.2.1).

``by_class(max_classes)`` reproduces the paper's setting: each local device
owns at most ``max_classes`` image classes ("non_IID_1" = 1 class/device).
``dirichlet`` is the standard LDA partitioner for ablations.  Both return a
list-of-index-arrays per (edge, device) so edges can have inconsistent J_i
(Fig. 4b).

Population-scale variants (PR 6) back ``repro.fl.population``: with a
device *population* far larger than the per-round cohort, materializing one
index array per device is O(population) memory for nothing.  Instead,

  * ``population_classes`` assigns classes to all P devices as one
    vectorized round-robin (same rule as ``by_class``: device ``d`` owns
    ``order[(d * max_classes + m) % n_classes]``) — P × max_classes i32,
    the only O(population) array the store keeps;
  * ``class_pools`` indexes the train split once into per-class pools;
  * ``sample_class_batches`` draws SGD batches for a *cohort* of devices
    directly from their classes' pools — O(cohort × steps × batch) work
    regardless of population size.

Unlike ``by_class`` (disjoint per-class slices), population shards are the
class pools themselves: two devices owning the same class sample from the
same pool (overlapping shards) — the standard cross-device regime where
per-round cohorts resample the population anyway.
"""
from __future__ import annotations

import numpy as np


def population_classes(population: int, n_classes: int, max_classes: int = 1,
                       seed=0) -> np.ndarray:
    """Vectorized round-robin class assignment for a device population.

    Returns ``[population, max_classes]`` i32 — the same assignment rule as
    ``by_class`` (a seed-shuffled class order walked round-robin so every
    class is covered), computed without per-device Python loops.  ``seed``
    may be an int or a ``SeedSequence``.
    """
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_classes)
    d = np.arange(population, dtype=np.int64)[:, None]
    m = np.arange(max_classes, dtype=np.int64)[None, :]
    return order[(d * max_classes + m) % n_classes].astype(np.int32)


def class_pools(labels: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Index ``labels`` into per-class sample pools, once.

    Returns ``(pool, offsets, counts)``: ``pool`` is a flat i32 array of
    sample indices sorted by class, class ``c`` owning the slice
    ``pool[offsets[c] : offsets[c] + counts[c]]``.
    """
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    pool = np.argsort(labels, kind="stable").astype(np.int32)
    counts = np.bincount(labels, minlength=n_classes).astype(np.int64)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int64)
    return pool, offsets, counts


def sample_class_batches(pool: np.ndarray, offsets: np.ndarray,
                         counts: np.ndarray, device_classes: np.ndarray,
                         steps: int, batch: int,
                         rng: np.random.Generator) -> np.ndarray:
    """Sample ``[D, steps, batch]`` train indices for a device cohort.

    ``device_classes``: ``[D, M]`` class assignment rows (from
    ``population_classes``, gathered for the cohort occupants).  Each draw
    first picks one of the device's M classes uniformly, then a uniform
    sample (with replacement) from that class's pool — one vectorized pass,
    no per-device loop.  Classes must be non-empty (``counts > 0``); the
    population store validates that once at construction.
    """
    D, M = device_classes.shape
    ci = rng.integers(0, M, size=(D, steps, batch))
    cls = device_classes[np.arange(D)[:, None, None], ci]
    draw = rng.integers(0, np.maximum(counts[cls], 1))
    return pool[offsets[cls] + draw].astype(np.int32)


def by_class(labels: np.ndarray, n_edges: int, j_per_edge: list[int],
             max_classes: int = 1, seed: int = 0) -> list[list[np.ndarray]]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    by_c = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for idx in by_c:
        rng.shuffle(idx)
    cursor = [0] * n_classes
    total_devices = sum(j_per_edge)
    # round-robin class assignment so all classes are covered across devices
    device_classes = []
    order = rng.permutation(n_classes)
    for d in range(total_devices):
        cls = [int(order[(d * max_classes + m) % n_classes])
               for m in range(max_classes)]
        device_classes.append(cls)
    per_class_share = {c: max(1, len(by_c[c]) // max(
        1, sum(c in dc for dc in device_classes))) for c in range(n_classes)}
    out, d = [], 0
    for e in range(n_edges):
        edge_parts = []
        for _ in range(j_per_edge[e]):
            chunks = []
            for c in device_classes[d]:
                share = per_class_share[c]
                lo = cursor[c]
                cursor[c] = min(lo + share, len(by_c[c]))
                chunks.append(by_c[c][lo:cursor[c]])
            edge_parts.append(np.concatenate(chunks) if chunks else
                              np.empty((0,), np.int64))
            d += 1
        out.append(edge_parts)
    return out


def dirichlet(labels: np.ndarray, n_edges: int, j_per_edge: list[int],
              alpha: float = 0.5, seed: int = 0) -> list[list[np.ndarray]]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    total = sum(j_per_edge)
    props = rng.dirichlet(np.full(total, alpha), size=n_classes)  # [C, D]
    device_idx: list[list[np.ndarray]] = [[] for _ in range(total)]
    for c in range(n_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        cuts = (np.cumsum(props[c])[:-1] * len(idx)).astype(int)
        for d, part in enumerate(np.split(idx, cuts)):
            device_idx[d].append(part)
    flat = [np.concatenate(p) if p else np.empty((0,), np.int64)
            for p in device_idx]
    out, d = [], 0
    for e in range(n_edges):
        out.append(flat[d:d + j_per_edge[e]])
        d += j_per_edge[e]
    return out
