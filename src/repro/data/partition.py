"""Non-IID partitioners (Sec. 6.1.1, 6.2.1).

``by_class(max_classes)`` reproduces the paper's setting: each local device
owns at most ``max_classes`` image classes ("non_IID_1" = 1 class/device).
``dirichlet`` is the standard LDA partitioner for ablations.  Both return a
list-of-index-arrays per (edge, device) so edges can have inconsistent J_i
(Fig. 4b).
"""
from __future__ import annotations

import numpy as np


def by_class(labels: np.ndarray, n_edges: int, j_per_edge: list[int],
             max_classes: int = 1, seed: int = 0) -> list[list[np.ndarray]]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    by_c = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for idx in by_c:
        rng.shuffle(idx)
    cursor = [0] * n_classes
    total_devices = sum(j_per_edge)
    # round-robin class assignment so all classes are covered across devices
    device_classes = []
    order = rng.permutation(n_classes)
    for d in range(total_devices):
        cls = [int(order[(d * max_classes + m) % n_classes])
               for m in range(max_classes)]
        device_classes.append(cls)
    per_class_share = {c: max(1, len(by_c[c]) // max(
        1, sum(c in dc for dc in device_classes))) for c in range(n_classes)}
    out, d = [], 0
    for e in range(n_edges):
        edge_parts = []
        for _ in range(j_per_edge[e]):
            chunks = []
            for c in device_classes[d]:
                share = per_class_share[c]
                lo = cursor[c]
                cursor[c] = min(lo + share, len(by_c[c]))
                chunks.append(by_c[c][lo:cursor[c]])
            edge_parts.append(np.concatenate(chunks) if chunks else
                              np.empty((0,), np.int64))
            d += 1
        out.append(edge_parts)
    return out


def dirichlet(labels: np.ndarray, n_edges: int, j_per_edge: list[int],
              alpha: float = 0.5, seed: int = 0) -> list[list[np.ndarray]]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    total = sum(j_per_edge)
    props = rng.dirichlet(np.full(total, alpha), size=n_classes)  # [C, D]
    device_idx: list[list[np.ndarray]] = [[] for _ in range(total)]
    for c in range(n_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        cuts = (np.cumsum(props[c])[:-1] * len(idx)).astype(int)
        for d, part in enumerate(np.split(idx, cuts)):
            device_idx[d].append(part)
    flat = [np.concatenate(p) if p else np.empty((0,), np.int64)
            for p in device_idx]
    out, d = [], 0
    for e in range(n_edges):
        out.append(flat[d:d + j_per_edge[e]])
        d += j_per_edge[e]
    return out
