"""Deterministic synthetic datasets.

MNIST is unavailable offline, so ``class_images`` generates an MNIST-shaped
surrogate: each of 10 classes is a fixed random prototype image; samples are
prototype + per-sample Gaussian noise + random shift.  The task is learnable
by the paper's CNN but not trivial (noise/shift force generalization), which
is what the paper's convergence comparisons need.

``lm_tokens`` provides token streams for the big-arch smoke tests: a mixture
of Markov chains so there is learnable next-token structure.
"""
from __future__ import annotations

import functools

import numpy as np


@functools.lru_cache(maxsize=8)
def class_images(n: int, seed: int = 0, hw: int = 28, n_classes: int = 10,
                 noise: float = 0.2, shift: int = 2
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images [n, hw, hw, 1] float32 in [0,1]-ish, labels [n]).

    Memoized: generation is a Python loop over n samples, and the sweep
    planner constructs one simulator per grid point — same-seed grids
    would otherwise regenerate the identical dataset P times.  The cached
    arrays are read-only so shared references cannot be corrupted; callers
    that need to write must copy.
    """
    rng = np.random.default_rng(seed)
    protos = rng.uniform(0.0, 1.0, size=(n_classes, hw, hw)).astype(np.float32)
    # smooth the prototypes so classes differ at low frequencies (digit-like)
    for _ in range(3):
        protos = 0.25 * (np.roll(protos, 1, 1) + np.roll(protos, -1, 1)
                         + np.roll(protos, 1, 2) + np.roll(protos, -1, 2))
    protos = (protos - protos.min((1, 2), keepdims=True)) \
        / np.ptp(protos, axis=(1, 2), keepdims=True).clip(1e-6)
    labels = rng.integers(0, n_classes, size=n)
    imgs = protos[labels].copy()
    dx = rng.integers(-shift, shift + 1, size=n)
    dy = rng.integers(-shift, shift + 1, size=n)
    for i in range(n):  # per-sample shift (vectorizing not worth it at our n)
        imgs[i] = np.roll(np.roll(imgs[i], dx[i], 0), dy[i], 1)
    imgs += rng.normal(0.0, noise, size=imgs.shape).astype(np.float32)
    imgs, labels = imgs[..., None], labels.astype(np.int32)
    imgs.flags.writeable = False
    labels.flags.writeable = False
    return imgs, labels


def lm_tokens(n_seqs: int, seq_len: int, vocab: int, seed: int = 0
              ) -> np.ndarray:
    """Markov-mixture token streams [n_seqs, seq_len] int32."""
    rng = np.random.default_rng(seed)
    k = min(vocab, 64)
    trans = rng.dirichlet(np.ones(k) * 0.1, size=k)
    out = np.zeros((n_seqs, seq_len), np.int64)
    state = rng.integers(0, k, size=n_seqs)
    for t in range(seq_len):
        out[:, t] = state
        u = rng.random((n_seqs, 1))
        state = (trans[state].cumsum(1) > u).argmax(1)
    return (out % vocab).astype(np.int32)
