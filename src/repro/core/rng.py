"""Collision-free RNG stream derivation for the simulator's host-side draws.

The simulator needs several independent randomness streams per deployment
seed — data synthesis, the non-IID partition, per-round batch sampling,
latency jitter, the straggler schedules (one per edge), the Raft chain,
and (population mode) the device-population profiles and cohort sampling.
These used to be derived ad hoc: ``seed + 17 * e`` for edge ``e``'s device
masks, ``seed + 991`` for the edge masks, ``[seed, 0x1A7E]`` for latency
jitter.  Affine offsets collide across (seed, stream) pairs — e.g.
``sim(seed=0)``'s edge-1 device masks were byte-identical to
``sim(seed=17)``'s edge-0 masks — so adjacent-seed grid points silently
shared straggler schedules instead of drawing independently.

Every stream is now derived through ``np.random.SeedSequence`` spawning,
which is designed for collision-free parallel stream derivation: child
sequences differ in their ``spawn_key``, not in arithmetic on the entropy,
so no (seed, stream) pair aliases another.

The ``STREAMS`` registry is **append-only**: each name owns a fixed spawn
position, so adding a stream never re-keys existing ones.  Switching the
derivation scheme was a documented one-time break of the exact draws
behind previously published figures (CHANGES.md, PR 6) — trajectories
change within seed-to-seed noise, invariants do not.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

#: Append-only registry of named streams.  Position = spawn index.
STREAMS = (
    "data",        # synthetic image generation (class_images)
    "partition",   # non-IID shard assignment (by_class / population classes)
    "batches",     # per-round SGD batch sampling (legacy loop + engine)
    "latency",     # per-device round-time jitter draws
    "edge_masks",  # edge-layer straggler schedule
    "dev_masks",   # device-layer straggler schedules (sub-spawned per edge)
    "chain",       # Raft election/commit timing
    "population",  # device-population profile synthesis
    "cohort",      # per-round cohort sampling
    "faults",      # fault-injection schedules (edge/validator churn, bursts,
    #                message loss) — see repro.fl.faults
)
_POS = {name: i for i, name in enumerate(STREAMS)}


def stream_seq(seed: int, name: str,
               index: Optional[int] = None) -> np.random.SeedSequence:
    """The ``SeedSequence`` for stream ``name`` of deployment ``seed``.

    ``index`` selects a sub-stream (e.g. one per edge for ``dev_masks``)
    via a second spawn level, so per-index streams are as independent of
    each other as the top-level streams are.
    """
    try:
        pos = _POS[name]
    except KeyError:
        raise KeyError(f"unknown RNG stream {name!r}; registered streams: "
                       f"{STREAMS}") from None
    child = np.random.SeedSequence(seed).spawn(len(STREAMS))[pos]
    if index is not None:
        if index < 0:
            raise ValueError(f"stream index must be >= 0, got {index}")
        child = child.spawn(index + 1)[index]
    return child


def stream_seed(seed: int, name: str, index: Optional[int] = None) -> int:
    """A hashable integer seed for stream ``name`` (for seed-keyed caches
    like ``data.synthetic.class_images`` and plain ``seed=`` APIs)."""
    return int(stream_seq(seed, name, index).generate_state(1, np.uint64)[0])


def stream_rng(seed: int, name: str,
               index: Optional[int] = None) -> np.random.Generator:
    """A fresh ``Generator`` on stream ``name`` of deployment ``seed``."""
    return np.random.default_rng(stream_seq(seed, name, index))
