"""HieAvg — the paper's hierarchical averaging aggregation (Sec. 3).

All functions are pytree-generic: participant weights are *stacked* pytrees
whose leaves carry a leading participant dimension ``n`` (clients of one edge
server, or edge servers at the global layer).  Straggler handling is driven by
a boolean ``mask`` of shape ``[n]`` (True = submitted in time).

Two aggregation layers (paper eqs. (2)-(5)):

  * edge layer   — unweighted mean over the J_i devices of edge i,
  * global layer — each edge model weighted by J_i / sum_i J_i.

Straggler estimation (Sec. 3.2.2): a straggler's missing submission is
estimated from its history,

    w_bar_s = w_s^{last} + E[Delta_s],     Delta = w^{last} - w^{prev},

scaled by the decay factor gamma = gamma0 * lambda**k' where k' >= 1 counts
consecutive missed rounds.  ``E[Delta]`` is a running mean of observed deltas.

Faithful vs. normalized mode
----------------------------
Eq. (4) divides the mixed sum by J_i even though straggler terms are shrunk by
gamma < 1, which biases the aggregate norm low as gamma decays (a permanent
straggler's slot decays toward a zero contribution).  We implement that
faithfully (``normalize=False``, the default — it is what the paper wrote) and
additionally offer a *beyond-paper* normalized mode that divides by
``M + sum_s gamma_s`` so the aggregate stays an affine combination
(``normalize=True``).  EXPERIMENTS.md §Perf ablates the two.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def _bshape(v: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Reshape a [n] vector so it broadcasts against a [n, ...] leaf."""
    return v.reshape(v.shape + (1,) * (leaf.ndim - 1))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class History:
    """Per-participant submission history used by the estimator.

    Leaves of ``prev_w`` / ``delta_mean`` have leading dim ``n`` matching the
    stacked participant weights.  ``n_obs`` counts observed deltas (for the
    running mean); ``miss_count`` counts consecutive missed rounds (the
    paper's k' / t').
    """

    prev_w: PyTree
    delta_mean: PyTree
    n_obs: jnp.ndarray      # [n] float32
    miss_count: jnp.ndarray  # [n] float32


def init_history(stacked_w: PyTree, dtype=None) -> History:
    """Cold-boot initialization from the first stacked submission (Alg. 1).

    After this call one more observed round is required before the delta
    history is meaningful — hence the paper's T_c >= 2 requirement, which
    ``repro.fl.simulator`` enforces.

    ``dtype`` overrides the history storage dtype — a beyond-paper knob:
    HieAvg's intrinsic memory cost is two extra model copies per hierarchy
    layer; bf16 cuts it 2× for free, ``jnp.float8_e4m3fn`` 4× at an
    accuracy cost (EXPERIMENTS.md §Perf, X1).  All estimation math stays
    f32 regardless (update_history casts).
    """
    leaves = jax.tree_util.tree_leaves(stacked_w)
    n = leaves[0].shape[0]
    cast = (lambda x: jnp.asarray(x, dtype)) if dtype is not None \
        else jnp.asarray
    return History(
        prev_w=jax.tree.map(cast, stacked_w),
        delta_mean=jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype),
                                stacked_w),
        n_obs=jnp.zeros((n,), jnp.float32),
        miss_count=jnp.zeros((n,), jnp.float32),
    )


def _estimate(history: History, gamma0: float, lam: float):
    """gamma_s * (w_prev + E[Delta]) and the decay factor per participant.

    miss_count passed in must already include the current missed round, so the
    first miss uses k' = 1 (paper: k' >= 1).
    """
    gamma = gamma0 * lam ** history.miss_count  # [n]
    est = jax.tree.map(lambda p, d: p + d, history.prev_w, history.delta_mean)
    return est, gamma


def update_history(history: History, stacked_w: PyTree, mask: jnp.ndarray) -> History:
    """Fold one round of submissions into the history.

    Present participants (mask True): delta = w - prev_w joins the running
    mean, prev_w <- w, miss_count <- 0.  Stragglers: prev_w is *extrapolated*
    by E[Delta] (so the next-round estimate keeps advancing, per Sec. 3.2.2's
    multi-round estimation), delta stats frozen, miss_count += 1.
    """
    m = mask.astype(jnp.float32)

    def upd_prev(prev, w, dmean):
        mb = _bshape(m, prev)
        out = mb * w.astype(jnp.float32) \
            + (1.0 - mb) * (prev + dmean).astype(jnp.float32)
        return out.astype(prev.dtype)   # keep storage dtype stable (bf16 ok)

    def upd_dmean(prev, w, dmean):
        mb = _bshape(m, prev)
        nb = _bshape(history.n_obs, prev)
        delta = w.astype(jnp.float32) - prev.astype(jnp.float32)
        new_mean = (dmean.astype(jnp.float32) * nb + delta) / (nb + 1.0)
        return (mb * new_mean
                + (1.0 - mb) * dmean.astype(jnp.float32)).astype(dmean.dtype)

    new_prev = jax.tree.map(upd_prev, history.prev_w, stacked_w, history.delta_mean)
    new_dmean = jax.tree.map(upd_dmean, history.prev_w, stacked_w, history.delta_mean)
    return History(
        prev_w=new_prev,
        delta_mean=new_dmean,
        n_obs=history.n_obs + m,
        miss_count=(history.miss_count + 1.0) * (1.0 - m),
    )


def _mix(stacked_w: PyTree, mask: jnp.ndarray, history: Optional[History],
         part_weights: jnp.ndarray, gamma0: float, lam: float,
         normalize: bool) -> PyTree:
    """Shared weighted mix for both layers.

    part_weights: [n] relative weight of each participant (1/J at the edge
    layer; J_i / sum J_i at the global layer).  Returns the aggregated pytree
    (no leading participant dim).
    """
    m = mask.astype(jnp.float32)
    if history is None:  # cold boot: everyone assumed present (Alg. 1)
        coef = part_weights
        est = None
        gamma = None
    else:
        # miss_count as of *this* round: stragglers' counter incremented now.
        bumped = dataclasses.replace(
            history, miss_count=(history.miss_count + 1.0) * (1.0 - m) + 0.0)
        # k' for current-round estimate = previous consecutive misses + 1
        est, gamma = _estimate(
            dataclasses.replace(history, miss_count=history.miss_count + 1.0),
            gamma0, lam)
        del bumped
        coef = part_weights * (m + (1.0 - m) * gamma)

    if normalize:
        coef = coef / jnp.maximum(jnp.sum(coef), 1e-12)

    def agg(w, e=None):
        cb = _bshape(coef, w)
        if e is None:
            return jnp.sum(cb * w, axis=0)
        mb = _bshape(m, w)
        return jnp.sum(cb * (mb * w + (1.0 - mb) * e), axis=0)

    if est is None:
        return jax.tree.map(agg, stacked_w)
    return jax.tree.map(agg, stacked_w, est)


def _mix_and_update(stacked_w: PyTree, mask: jnp.ndarray, history: History,
                    part_weights: jnp.ndarray, gamma0: float, lam: float,
                    normalize: bool) -> tuple[PyTree, History]:
    """Aggregate (eq. 4/5) + history update in ONE pass per leaf.

    The separate _mix / update_history formulation walks every [n, ...]
    leaf twice with fresh f32 intermediates — at 16B-parameter trees that
    is several live f32 copies of the model at peak.  Fusing both into one
    tree.map shares the (prev + Δ̄) estimate and lets XLA schedule leaf by
    leaf (the XLA analogue of kernels/hieavg_agg, which fuses the same
    chain into one HBM pass on TPU).
    """
    m = mask.astype(jnp.float32)
    gamma = gamma0 * lam ** (history.miss_count + 1.0)   # k' >= 1
    coef = part_weights * (m + (1.0 - m) * gamma)
    if normalize:
        coef = coef / jnp.maximum(jnp.sum(coef), 1e-12)
    coef_p = coef * m                    # weight on the real submission
    coef_e = coef * (1.0 - m)            # weight on the estimate
    nb1 = history.n_obs + 1.0

    def one(w, prev, dmean):
        f32 = jnp.float32
        wf, pf, df = w.astype(f32), prev.astype(f32), dmean.astype(f32)
        est = pf + df
        agg = jnp.sum(_bshape(coef_p, wf) * wf + _bshape(coef_e, wf) * est,
                      axis=0)
        mb = _bshape(m, wf)
        new_prev = (mb * wf + (1.0 - mb) * est).astype(prev.dtype)
        new_mean = (df * _bshape(history.n_obs, wf) + (wf - pf)) \
            / _bshape(nb1, wf)
        new_dmean = (mb * new_mean + (1.0 - mb) * df).astype(dmean.dtype)
        return agg, new_prev, new_dmean

    triples = jax.tree.map(one, stacked_w, history.prev_w,
                           history.delta_mean)
    treedef = jax.tree_util.tree_structure(stacked_w)
    leaves = treedef.flatten_up_to(triples)
    agg = jax.tree_util.tree_unflatten(treedef, [t[0] for t in leaves])
    new_hist = History(
        prev_w=jax.tree_util.tree_unflatten(treedef, [t[1] for t in leaves]),
        delta_mean=jax.tree_util.tree_unflatten(treedef,
                                                [t[2] for t in leaves]),
        n_obs=history.n_obs + m,
        miss_count=(history.miss_count + 1.0) * (1.0 - m),
    )
    return agg, new_hist


@partial(jax.jit, static_argnames=("gamma0", "lam", "normalize"))
def edge_aggregate(stacked_w: PyTree, mask: jnp.ndarray, history: History,
                   *, gamma0: float = 0.9, lam: float = 0.9,
                   normalize: bool = False) -> tuple[PyTree, History]:
    """Eq. (4): edge aggregation with straggler estimation.

    Returns (edge model w_i^{t,k}, updated history).
    """
    n = mask.shape[0]
    pw = jnp.full((n,), 1.0 / n, jnp.float32)
    return _mix_and_update(stacked_w, mask, history, pw, gamma0, lam,
                           normalize)


@partial(jax.jit, static_argnames=("gamma0", "lam", "normalize"))
def global_aggregate(stacked_w: PyTree, mask: jnp.ndarray, history: History,
                     j_per_edge: jnp.ndarray, *, gamma0: float = 0.9,
                     lam: float = 0.9, normalize: bool = False
                     ) -> tuple[PyTree, History]:
    """Eq. (5): global aggregation on the edge leader, J_i-weighted."""
    pw = j_per_edge.astype(jnp.float32) / jnp.sum(j_per_edge)
    return _mix_and_update(stacked_w, mask, history, pw, gamma0, lam,
                           normalize)


def aggregate(stacked_w: PyTree, mask: jnp.ndarray, history: History,
              part_weights: jnp.ndarray, gamma0, lam,
              normalize: bool = False) -> tuple[PyTree, History]:
    """Trace-friendly eq. (4)/(5): like ``edge_aggregate``/``global_aggregate``
    but with ``gamma0``/``lam`` as (possibly traced) values and no jit
    boundary, so it composes under ``vmap``/``scan`` inside a larger program
    (the batched engine sweeps gamma/lambda as data, not as recompiles).
    ``part_weights`` is taken as-is (pre-normalized by the caller)."""
    return _mix_and_update(stacked_w, mask, history, part_weights, gamma0,
                           lam, normalize)


# ------------------------------------------------- batched (dense) layer API
# The fl.engine drives all N edges at once: stacked weights carry TWO leading
# dims [N, J, ...] (edge, device-slot), histories likewise, and a boolean
# ``valid`` [N, J] marks real device slots (False = ragged-J padding).  Padded
# slots get part-weight 0 so they contribute exactly nothing to the mix, and
# their history entries are dead state that is never read back.

def init_history_batched(stacked_w: PyTree, dtype=None) -> History:
    """Cold-boot history for dense [N, J, ...] stacked weights.

    ``dtype`` mirrors ``init_history``'s storage-dtype knob (EXPERIMENTS.md
    X1): histories are two extra model copies per participant per layer;
    bf16 storage cuts that 2× at no measured accuracy cost, f8 4× with an
    accuracy penalty.  The estimation math stays f32 either way.
    """
    leaves = jax.tree_util.tree_leaves(stacked_w)
    n, j = leaves[0].shape[:2]
    cast = (lambda x: jnp.asarray(x, dtype)) if dtype is not None \
        else jnp.asarray
    return History(
        prev_w=jax.tree.map(cast, stacked_w),
        delta_mean=jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype),
                                stacked_w),
        n_obs=jnp.zeros((n, j), jnp.float32),
        miss_count=jnp.zeros((n, j), jnp.float32),
    )


def update_history_batched(history: History, stacked_w: PyTree,
                           mask: jnp.ndarray) -> History:
    """``update_history`` vmapped over the leading edge dim."""
    return jax.vmap(update_history)(history, stacked_w, mask)


def edge_aggregate_batched(stacked_w: PyTree, mask: jnp.ndarray,
                           history: History, valid: jnp.ndarray,
                           gamma0, lam, normalize: bool = False
                           ) -> tuple[PyTree, History]:
    """Eq. (4) for ALL N edges in one vmapped ``_mix_and_update`` call.

    stacked_w leaves [N, J, ...]; mask/valid [N, J]; history leaves likewise.
    Per-edge part weights are ``valid / J_e`` — identical to the legacy
    ``1/J_e`` on real slots, zero on padding.  Returns ([N, ...] edge models,
    updated batched history).
    """
    v = valid.astype(jnp.float32)
    pw = v / jnp.maximum(jnp.sum(v, axis=-1, keepdims=True), 1.0)

    def one_edge(w, m, h, p):
        return _mix_and_update(w, m, h, p, gamma0, lam, normalize)

    return jax.vmap(one_edge)(stacked_w, mask, history, pw)


def edge_aggregate_cold_batched(stacked_w: PyTree, valid: jnp.ndarray
                                ) -> PyTree:
    """Eq. (2) for all edges at once: per-edge mean over *valid* slots."""
    return jax.vmap(global_aggregate_cold)(stacked_w,
                                           valid.astype(jnp.float32))


@jax.jit
def edge_aggregate_cold(stacked_w: PyTree) -> PyTree:
    """Eq. (2) during cold boot — plain mean over devices (no stragglers)."""
    return jax.tree.map(lambda w: jnp.mean(w, axis=0), stacked_w)


@jax.jit
def global_aggregate_cold(stacked_w: PyTree, j_per_edge: jnp.ndarray) -> PyTree:
    """Eq. (3) during cold boot — J_i-weighted mean over edge models.

    An all-zero ``j_per_edge`` (a sweep-fabric padded edge whose slots are
    all invalid) aggregates to exact zeros instead of dividing by zero —
    the padded edge model must stay finite so its downstream zero-weight
    contributions are true no-ops.
    """
    pw = j_per_edge.astype(jnp.float32) \
        / jnp.maximum(jnp.sum(j_per_edge), 1e-12)

    def agg(w):
        return jnp.sum(_bshape(pw, w) * w, axis=0)

    return jax.tree.map(agg, stacked_w)
