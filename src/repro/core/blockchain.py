"""Raft-based consortium blockchain (Sec. 2.3) — discrete-event simulation.

The blockchain is a control-plane protocol among edge servers; it has no TPU
compute analogue (see DESIGN.md §3), so we implement it as a faithful,
latency-accounted simulation:

  * Leader election — randomized election timeouts, term counting, majority
    votes (Raft §5.2).  Runs *before* global aggregation, overlapped with the
    K edge rounds, exactly as the paper requires to hide consensus latency.
  * Model submission — followers send edge models to the leader.
  * Block generation — the leader packages all edge models + the new global
    model into a block (hash-chained), replicates it, and commits on majority
    acknowledgement.

Every operation returns elapsed simulated time; ``consensus_latency()`` feeds
constraint C2 of the latency optimization (Sec. 5).

``ConsensusChain`` is the pluggable consensus-model interface (the MC half
of a *consensus model*; the closed-form half is the expected-latency/energy
pair each protocol registers in ``repro.core.consensus``).  ``RaftChain`` is
the paper's protocol; the PoFEL and sharded-chain alternatives live in
``repro.core.consensus``.  Every chain also accrues cumulative protocol
*energy* (Joules) on ``.energy`` — the second traced cost axis beside the
simulated clock.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Any, Optional

import numpy as np


def _hash_payload(payload: Any) -> str:
    def default(o):
        if isinstance(o, np.ndarray):
            return hashlib.sha256(o.tobytes()).hexdigest()
        if hasattr(o, "tolist"):
            return o.tolist()
        return repr(o)
    blob = json.dumps(payload, sort_keys=True, default=default).encode()
    return hashlib.sha256(blob).hexdigest()


@dataclasses.dataclass
class Block:
    index: int
    term: int
    prev_hash: str
    payload_hash: str      # hash over {edge models, global model}
    leader: int
    timestamp: float       # simulated seconds since genesis

    @property
    def hash(self) -> str:
        return _hash_payload(dataclasses.asdict(self))


@dataclasses.dataclass
class RaftParams:
    """Timing parameters (seconds).  Defaults follow the paper's measured
    setup: ~0.05 s edge-to-edge link latency (Sec. 6.2.2, citing [8])."""
    link_latency: float = 0.05          # one-way edge<->edge message
    election_timeout: tuple[float, float] = (0.15, 0.30)  # Raft's range
    heartbeat_interval: float = 0.05
    block_serialize: float = 0.01       # leader-side block packaging
    e_msg: float = 0.05                 # J per protocol message (energy axis)


class ConsensusChain:
    """The pluggable consensus-model interface: shared block lifecycle.

    One instance per BHFL deployment; the engine drives it once per global
    round as ``elect_leader()`` → ``commit_block()``.  Subclass contract
    (what ``repro.fl.engine.replay_chain`` and the simulator rely on):

      * ``elect_leader() -> (leader id, elapsed s)`` — the per-round
        agreement phase (Raft's vote, PoFEL's candidate scoring, a sharded
        chain's intra-shard rounds).  MUST raise ``RuntimeError`` matching
        "no majority alive" when fewer than a quorum of nodes is alive —
        never spin (the PR 3 fix, extended zoo-wide).
      * ``commit_block(edges, global) -> (Block, elapsed s)`` — package
        the round's models into a hash-chained block and finalize it.
        Same below-quorum raise.
      * ``.energy`` — cumulative protocol energy in Joules, accrued by
        both phases; ``replay_chain`` differences it per round into the
        engine's ``cons_energy`` plane.
      * ``fail_node``/``recover_node`` and the ``.alive`` mask — leader
        failover drills mutate these mid-run.
      * ``.blocks`` (genesis at index 0) and ``validate()`` — chain
        integrity, reported per run as ``RunResult.blocks``/``chain_valid``.

    The closed-form half of a consensus model (expected per-round latency
    and energy as a function of its params and the alive count) lives next
    to each protocol and is registered in ``repro.core.consensus``; the
    hypothesis-driven Monte-Carlo pins (tests/test_consensus_zoo.py,
    ``-m consensus_mc``) hold the two halves together within 5%.
    """

    def __init__(self, n_nodes: int, seed: int = 0):
        if n_nodes < 1:
            raise ValueError("need at least one edge server")
        self.n = n_nodes
        self.rng = np.random.default_rng(seed)
        self.term = 0
        self.leader: Optional[int] = None
        self.clock = 0.0
        self.energy = 0.0               # cumulative protocol Joules
        genesis = Block(0, 0, "0" * 64, _hash_payload("genesis"), -1, 0.0)
        self.blocks: list[Block] = [genesis]
        self.alive = np.ones(n_nodes, dtype=bool)

    # ------------------------------------------------------------ membership
    def fail_node(self, i: int) -> None:
        self.alive[i] = False
        if self.leader == i:
            self.leader = None

    def recover_node(self, i: int) -> None:
        self.alive[i] = True

    def n_alive(self) -> int:
        return int(self.alive.sum())

    def _require_majority(self) -> int:
        """Quorum gate: returns the alive count, raising below majority."""
        a = self.n_alive()
        if a == 0:
            raise RuntimeError("no live edge servers")
        if a < self.n // 2 + 1:
            raise RuntimeError(
                f"no majority alive ({a}/{self.n} nodes): "
                "consensus can never be reached")
        return a

    # ------------------------------------------------------------- protocol
    def elect_leader(self) -> tuple[int, float]:
        raise NotImplementedError

    def commit_block(self, edge_models_digest: Any, global_model_digest: Any
                     ) -> tuple[Block, float]:
        raise NotImplementedError

    def _append_block(self, payload: Any, elapsed: float) -> Block:
        """Hash-chain the payload onto the tip and advance the clock."""
        block = Block(
            index=len(self.blocks),
            term=self.term,
            prev_hash=self.blocks[-1].hash,
            payload_hash=_hash_payload(payload),
            leader=self.leader,
            timestamp=self.clock,
        )
        self.blocks.append(block)
        self.clock += elapsed
        return block

    # ------------------------------------------------------------ integrity
    def validate(self) -> bool:
        for prev, blk in zip(self.blocks, self.blocks[1:]):
            if blk.prev_hash != prev.hash or blk.index != prev.index + 1:
                return False
        return True


class RaftChain(ConsensusChain):
    """N edge servers running Raft; one instance per BHFL deployment."""

    def __init__(self, n_nodes: int, params: Optional[RaftParams] = None,
                 seed: int = 0):
        super().__init__(n_nodes, seed)
        self.params = params or RaftParams()

    # ------------------------------------------------------------------ raft
    def elect_leader(self) -> tuple[int, float]:
        """Randomized-timeout election; returns (leader id, elapsed time).

        The node whose timeout fires first requests votes; it wins if a
        majority of nodes is alive (consortium setting: no byzantine voters).
        Re-draws on split timeouts within 1ms, like Raft's re-election.
        Raises ``RuntimeError`` when fewer than a majority of the N nodes
        are alive — the win condition can never hold, and silently looping
        forever (the pre-fix behaviour) hid the quorum loss from callers.

        Energy: each attempt costs one RequestVote fan-out + the vote
        replies — ``2·(A-1)`` messages at ``e_msg`` Joules each.
        """
        elapsed = 0.0
        while True:
            self.term += 1
            lo, hi = self.params.election_timeout
            alive_ids = np.flatnonzero(self.alive)
            if alive_ids.size == 0:
                raise RuntimeError("no live edge servers")
            if alive_ids.size < self.n // 2 + 1:
                raise RuntimeError(
                    f"no majority alive ({alive_ids.size}/{self.n} nodes): "
                    "a leader can never win the vote")
            timeouts = self.rng.uniform(lo, hi, size=alive_ids.size)
            order = np.argsort(timeouts)
            first, t_first = alive_ids[order[0]], timeouts[order[0]]
            split = timeouts.size > 1 and (timeouts[order[1]] - t_first) < 1e-3
            # candidate timeout + RequestVote round trip to majority
            elapsed += t_first + 2 * self.params.link_latency
            self.energy += 2.0 * (alive_ids.size - 1) * self.params.e_msg
            if self.alive.sum() >= self.n // 2 + 1 and not split:
                self.leader = int(first)
                self.clock += elapsed
                return self.leader, elapsed
            # split vote: try again (elapsed keeps accumulating)

    # ------------------------------------------------------ block lifecycle
    def commit_block(self, edge_models_digest: Any, global_model_digest: Any
                     ) -> tuple[Block, float]:
        """Leader packages + replicates a block; commits on majority ack.

        Returns (block, elapsed time).  Elapsed = serialize + AppendEntries
        round trip; with a failed leader an election is run first.  Energy:
        the AppendEntries fan-out + acks — ``2·(A-1)`` messages.
        """
        elapsed = 0.0
        if self.leader is None or not self.alive[self.leader]:
            _, t = self.elect_leader()
            elapsed += t
        payload = {"edges": edge_models_digest, "global": global_model_digest,
                   "term": self.term}
        elapsed += self.params.block_serialize + 2 * self.params.link_latency
        if self.alive.sum() < self.n // 2 + 1:
            raise RuntimeError("cannot commit: no majority alive")
        self.energy += 2.0 * (self.n_alive() - 1) * self.params.e_msg
        block = self._append_block(payload, elapsed)
        return block, elapsed

    def consensus_latency(self) -> float:
        """Expected per-round consensus latency L_bc (election amortized out:
        the paper overlaps election with edge rounds, so steady-state L_bc is
        block replication only)."""
        return self.params.block_serialize + 2 * self.params.link_latency


# --------------------------------------------------- statistical model
# Closed-form expectations of the discrete-event simulation above, used by
# the latency fabric (repro.core.latency / repro.fl.sweep) so consensus
# latency can be swept without replaying a RaftChain per grid point.  The
# discrete-event ``RaftChain`` stays the reference implementation;
# tests/test_latency_fabric.py pins these expectations against Monte-Carlo
# replay over a link_latency x N grid.

_SPLIT_EPS = 1e-3   # elect_leader's split-vote window (two timeouts < 1ms)


def expected_election_latency(params: RaftParams, n_nodes: int,
                              n_alive: Optional[int] = None) -> float:
    """E[elapsed] of ``RaftChain.elect_leader`` with ``n_alive`` live nodes.

    One attempt costs ``t_first + 2 * link_latency`` where ``t_first`` is
    the minimum of A iid U(lo, hi) timeouts: ``E[t_first] = lo + w/(A+1)``.
    An attempt fails on a split vote — the gap between the two smallest of
    A uniforms on a width-``w`` window falls under eps with probability
    ``1 - (1 - eps/w)^A`` (each consecutive uniform spacing is
    Beta(1, A)-scaled: for A=2, P(|X1-X2| > d) = (1 - d/w)^2) — so the
    attempt count is geometric and the expectation divides by the
    per-attempt success probability.  The tiny
    negative correlation between ``t_first`` and the first spacing is
    ignored (eps/w ~ 0.7%); the Monte-Carlo pin budgets for it.

    Returns ``inf`` when fewer than a majority of ``n_nodes`` is alive
    (``elect_leader`` raises in that regime — no finite expectation
    exists).
    """
    a = n_nodes if n_alive is None else n_alive
    if a < n_nodes // 2 + 1:
        return float("inf")
    lo, hi = params.election_timeout
    w = hi - lo
    e_first = lo + w / (a + 1.0)
    p_split = 1.0 - (1.0 - _SPLIT_EPS / w) ** a if a > 1 else 0.0
    return (e_first + 2.0 * params.link_latency) / (1.0 - p_split)


def expected_consensus_latency(params: RaftParams, n_nodes: int,
                               n_alive: Optional[int] = None,
                               include_election: bool = True) -> float:
    """Expected per-global-round consensus latency L_bc.

    Replication (serialize + AppendEntries round trip) is always on the
    round's critical path; the election runs once per round in the BHFL
    workflow and is included by default.  ``include_election=False`` gives
    the steady-state replication-only figure, identical to
    ``RaftChain.consensus_latency()`` (the paper amortizes the election
    into the edge window).
    """
    lbc = params.block_serialize + 2.0 * params.link_latency
    if include_election:
        lbc += expected_election_latency(params, n_nodes, n_alive)
    return lbc


def expected_consensus_energy(params: RaftParams, n_nodes: int,
                              n_alive: Optional[int] = None) -> float:
    """E[energy] of one elect+commit Raft round, in Joules.

    Message counting: every election attempt is a RequestVote fan-out plus
    the vote replies (``2·(A-1)`` messages), the commit is an AppendEntries
    fan-out plus acks (another ``2·(A-1)``).  The attempt count is the same
    split-vote geometric as ``expected_election_latency`` —
    ``E[attempts] = 1/(1 - p_split)`` — so

        E[J/round] = e_msg · 2·(A-1) · (E[attempts] + 1).

    Returns ``inf`` below quorum (the chain raises there).
    """
    a = n_nodes if n_alive is None else n_alive
    if a < n_nodes // 2 + 1:
        return float("inf")
    lo, hi = params.election_timeout
    w = hi - lo
    p_split = 1.0 - (1.0 - _SPLIT_EPS / w) ** a if a > 1 else 0.0
    e_attempts = 1.0 / (1.0 - p_split)
    return params.e_msg * 2.0 * (a - 1) * (e_attempts + 1.0)
