"""Raft-based consortium blockchain (Sec. 2.3) — discrete-event simulation.

The blockchain is a control-plane protocol among edge servers; it has no TPU
compute analogue (see DESIGN.md §3), so we implement it as a faithful,
latency-accounted simulation:

  * Leader election — randomized election timeouts, term counting, majority
    votes (Raft §5.2).  Runs *before* global aggregation, overlapped with the
    K edge rounds, exactly as the paper requires to hide consensus latency.
  * Model submission — followers send edge models to the leader.
  * Block generation — the leader packages all edge models + the new global
    model into a block (hash-chained), replicates it, and commits on majority
    acknowledgement.

Every operation returns elapsed simulated time; ``consensus_latency()`` feeds
constraint C2 of the latency optimization (Sec. 5).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Any, Optional

import numpy as np


def _hash_payload(payload: Any) -> str:
    def default(o):
        if isinstance(o, np.ndarray):
            return hashlib.sha256(o.tobytes()).hexdigest()
        if hasattr(o, "tolist"):
            return o.tolist()
        return repr(o)
    blob = json.dumps(payload, sort_keys=True, default=default).encode()
    return hashlib.sha256(blob).hexdigest()


@dataclasses.dataclass
class Block:
    index: int
    term: int
    prev_hash: str
    payload_hash: str      # hash over {edge models, global model}
    leader: int
    timestamp: float       # simulated seconds since genesis

    @property
    def hash(self) -> str:
        return _hash_payload(dataclasses.asdict(self))


@dataclasses.dataclass
class RaftParams:
    """Timing parameters (seconds).  Defaults follow the paper's measured
    setup: ~0.05 s edge-to-edge link latency (Sec. 6.2.2, citing [8])."""
    link_latency: float = 0.05          # one-way edge<->edge message
    election_timeout: tuple[float, float] = (0.15, 0.30)  # Raft's range
    heartbeat_interval: float = 0.05
    block_serialize: float = 0.01       # leader-side block packaging


class RaftChain:
    """N edge servers running Raft; one instance per BHFL deployment."""

    def __init__(self, n_nodes: int, params: Optional[RaftParams] = None,
                 seed: int = 0):
        if n_nodes < 1:
            raise ValueError("need at least one edge server")
        self.n = n_nodes
        self.params = params or RaftParams()
        self.rng = np.random.default_rng(seed)
        self.term = 0
        self.leader: Optional[int] = None
        self.clock = 0.0
        genesis = Block(0, 0, "0" * 64, _hash_payload("genesis"), -1, 0.0)
        self.blocks: list[Block] = [genesis]
        self.alive = np.ones(n_nodes, dtype=bool)

    # ------------------------------------------------------------------ raft
    def elect_leader(self) -> tuple[int, float]:
        """Randomized-timeout election; returns (leader id, elapsed time).

        The node whose timeout fires first requests votes; it wins if a
        majority of nodes is alive (consortium setting: no byzantine voters).
        Re-draws on split timeouts within 1ms, like Raft's re-election.
        Raises ``RuntimeError`` when fewer than a majority of the N nodes
        are alive — the win condition can never hold, and silently looping
        forever (the pre-fix behaviour) hid the quorum loss from callers.
        """
        elapsed = 0.0
        while True:
            self.term += 1
            lo, hi = self.params.election_timeout
            alive_ids = np.flatnonzero(self.alive)
            if alive_ids.size == 0:
                raise RuntimeError("no live edge servers")
            if alive_ids.size < self.n // 2 + 1:
                raise RuntimeError(
                    f"no majority alive ({alive_ids.size}/{self.n} nodes): "
                    "a leader can never win the vote")
            timeouts = self.rng.uniform(lo, hi, size=alive_ids.size)
            order = np.argsort(timeouts)
            first, t_first = alive_ids[order[0]], timeouts[order[0]]
            split = timeouts.size > 1 and (timeouts[order[1]] - t_first) < 1e-3
            # candidate timeout + RequestVote round trip to majority
            elapsed += t_first + 2 * self.params.link_latency
            if self.alive.sum() >= self.n // 2 + 1 and not split:
                self.leader = int(first)
                self.clock += elapsed
                return self.leader, elapsed
            # split vote: try again (elapsed keeps accumulating)

    def fail_node(self, i: int) -> None:
        self.alive[i] = False
        if self.leader == i:
            self.leader = None

    def recover_node(self, i: int) -> None:
        self.alive[i] = True

    # ------------------------------------------------------ block lifecycle
    def commit_block(self, edge_models_digest: Any, global_model_digest: Any
                     ) -> tuple[Block, float]:
        """Leader packages + replicates a block; commits on majority ack.

        Returns (block, elapsed time).  Elapsed = serialize + AppendEntries
        round trip; with a failed leader an election is run first.
        """
        elapsed = 0.0
        if self.leader is None or not self.alive[self.leader]:
            _, t = self.elect_leader()
            elapsed += t
        payload = {"edges": edge_models_digest, "global": global_model_digest,
                   "term": self.term}
        block = Block(
            index=len(self.blocks),
            term=self.term,
            prev_hash=self.blocks[-1].hash,
            payload_hash=_hash_payload(payload),
            leader=self.leader,
            timestamp=self.clock,
        )
        elapsed += self.params.block_serialize + 2 * self.params.link_latency
        if self.alive.sum() < self.n // 2 + 1:
            raise RuntimeError("cannot commit: no majority alive")
        self.blocks.append(block)
        self.clock += elapsed
        return block, elapsed

    def consensus_latency(self) -> float:
        """Expected per-round consensus latency L_bc (election amortized out:
        the paper overlaps election with edge rounds, so steady-state L_bc is
        block replication only)."""
        return self.params.block_serialize + 2 * self.params.link_latency

    # ------------------------------------------------------------ integrity
    def validate(self) -> bool:
        for prev, blk in zip(self.blocks, self.blocks[1:]):
            if blk.prev_hash != prev.hash or blk.index != prev.index + 1:
                return False
        return True


# --------------------------------------------------- statistical model
# Closed-form expectations of the discrete-event simulation above, used by
# the latency fabric (repro.core.latency / repro.fl.sweep) so consensus
# latency can be swept without replaying a RaftChain per grid point.  The
# discrete-event ``RaftChain`` stays the reference implementation;
# tests/test_latency_fabric.py pins these expectations against Monte-Carlo
# replay over a link_latency x N grid.

_SPLIT_EPS = 1e-3   # elect_leader's split-vote window (two timeouts < 1ms)


def expected_election_latency(params: RaftParams, n_nodes: int,
                              n_alive: Optional[int] = None) -> float:
    """E[elapsed] of ``RaftChain.elect_leader`` with ``n_alive`` live nodes.

    One attempt costs ``t_first + 2 * link_latency`` where ``t_first`` is
    the minimum of A iid U(lo, hi) timeouts: ``E[t_first] = lo + w/(A+1)``.
    An attempt fails on a split vote — the gap between the two smallest of
    A uniforms on a width-``w`` window falls under eps with probability
    ``1 - (1 - eps/w)^A`` (each consecutive uniform spacing is
    Beta(1, A)-scaled: for A=2, P(|X1-X2| > d) = (1 - d/w)^2) — so the
    attempt count is geometric and the expectation divides by the
    per-attempt success probability.  The tiny
    negative correlation between ``t_first`` and the first spacing is
    ignored (eps/w ~ 0.7%); the Monte-Carlo pin budgets for it.

    Returns ``inf`` when fewer than a majority of ``n_nodes`` is alive
    (``elect_leader`` raises in that regime — no finite expectation
    exists).
    """
    a = n_nodes if n_alive is None else n_alive
    if a < n_nodes // 2 + 1:
        return float("inf")
    lo, hi = params.election_timeout
    w = hi - lo
    e_first = lo + w / (a + 1.0)
    p_split = 1.0 - (1.0 - _SPLIT_EPS / w) ** a if a > 1 else 0.0
    return (e_first + 2.0 * params.link_latency) / (1.0 - p_split)


def expected_consensus_latency(params: RaftParams, n_nodes: int,
                               n_alive: Optional[int] = None,
                               include_election: bool = True) -> float:
    """Expected per-global-round consensus latency L_bc.

    Replication (serialize + AppendEntries round trip) is always on the
    round's critical path; the election runs once per round in the BHFL
    workflow and is included by default.  ``include_election=False`` gives
    the steady-state replication-only figure, identical to
    ``RaftChain.consensus_latency()`` (the paper amortizes the election
    into the edge window).
    """
    lbc = params.block_serialize + 2.0 * params.link_latency
    if include_election:
        lbc += expected_election_latency(params, n_nodes, n_alive)
    return lbc
