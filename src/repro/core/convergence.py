"""Convergence bound Omega (Theorem 2 RHS) used as constraint C1 in Sec. 5.2.

Theorem 2 bounds the mean squared gradient norm of the global loss:

    (1/T) sum_t E||grad F(w_t)||^2
      <= 2 [F(w0) - F(w*) + sqrt(K) * eta * rho * delta''^2] / (sqrt(T) * D)
       + (2 + L) * [rho + gamma0 * (S/N) * (Delta_i + delta_i^2) - delta_bar'] / D

    with  rho = E[J_s] / (N * E[J_i]),
          D   = 2 sqrt(K) * eta * rho + L * eta - 1.

The constants (L, delta''_sq, Delta_i, delta_i_sq, delta_bar_p, F-gap) are not
observable a priori; ``BoundParams.from_trace`` estimates them from a short
training trace, which is how the paper's experiments implicitly instantiate
the bound when solving for K*.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class BoundParams:
    L: float = 10.0              # Lipschitz constant of grad F
    # Theorem 2 requires eta >= 1/(L + 2K rho), i.e. eta on the order of 1/L;
    # smaller eta makes the bound's denominator negative (theorem vacuous).
    eta: float = 0.12            # E[eta^{t,k}]
    f_gap: float = 2.3           # F(w0) - F(w*)
    delta_pp_sq: float = 0.5     # delta''^2 — edge-gradient variance
    Delta_i: float = 0.01        # E[weight-difference drift] (Assumption 2.1)
    delta_i_sq: float = 0.01     # its variance bound
    delta_bar_p: float = 0.0     # delta_bar' — estimated-weight deviation
    gamma0: float = 0.9
    s_frac: float = 0.2          # E[S^t] / N — straggler fraction at edges
    j_ratio: float = 0.2         # rho = E[J_s] / (N E[J_i])
    T: int = 50

    @staticmethod
    def from_trace(losses: Sequence[float], grad_norms: Sequence[float],
                   weight_deltas: Sequence[float], eta: float, gamma0: float,
                   s_frac: float, j_ratio: float, T: int) -> "BoundParams":
        """Estimate the bound constants from an observed training trace.

        L from the grad-norm / weight-delta ratio (secant estimate of the
        Lipschitz constant); variances from trace dispersion.
        """
        losses = np.asarray(losses, dtype=np.float64)
        g = np.asarray(grad_norms, dtype=np.float64)
        d = np.asarray(weight_deltas, dtype=np.float64)
        dg = np.abs(np.diff(g))
        L = float(np.median(dg / np.maximum(d[: dg.size], 1e-9))) if dg.size else 10.0
        return BoundParams(
            L=max(L, 1e-3),
            eta=eta,
            f_gap=float(max(losses[0] - losses.min(), 1e-3)),
            delta_pp_sq=float(np.var(g)) if g.size > 1 else 0.5,
            Delta_i=float(np.mean(d)) if d.size else 0.01,
            delta_i_sq=float(np.var(d)) if d.size > 1 else 0.01,
            delta_bar_p=0.0,
            gamma0=gamma0, s_frac=s_frac, j_ratio=j_ratio, T=T,
        )


def omega_bound(K: int, p: BoundParams) -> float:
    """Theorem 2's upper bound Omega as a function of K.

    Valid under the theorem's step-size condition (denominator D > 0); we
    return +inf outside the valid region so the optimizer treats it as
    infeasible rather than exploiting a negative denominator.
    """
    rho = p.j_ratio
    denom = 2.0 * math.sqrt(K) * p.eta * rho + p.L * p.eta - 1.0
    if denom <= 0:
        return float("inf")
    term1 = 2.0 * (p.f_gap + math.sqrt(K) * p.eta * rho * p.delta_pp_sq) \
        / (math.sqrt(p.T) * denom)
    straggler_pen = rho + p.gamma0 * p.s_frac * (p.Delta_i + p.delta_i_sq) \
        - p.delta_bar_p
    term2 = (2.0 + p.L) * straggler_pen / denom
    return term1 + term2


def omega_bound_k(p: BoundParams, k_max: int):
    """Omega over the dense ``[k_max]`` axis K = 1..k_max — traced ``jnp``.

    The latency fabric's companion to ``repro.core.latency.total_latency_k``
    / ``edge_window_k``: feeds ``optimize_k_masked`` so a whole grid of K*
    solves batches into one call.  +inf outside the step-size-valid region
    (denominator <= 0), like the scalar reference; fields of ``p`` may be
    traced scalars.
    """
    import jax.numpy as jnp

    sqrt_k = jnp.sqrt(jnp.arange(1, k_max + 1, dtype=jnp.float32))
    rho = p.j_ratio
    denom = 2.0 * sqrt_k * p.eta * rho + p.L * p.eta - 1.0
    term1 = 2.0 * (p.f_gap + sqrt_k * p.eta * rho * p.delta_pp_sq) \
        / (jnp.sqrt(jnp.float32(p.T)) * denom)
    straggler_pen = rho + p.gamma0 * p.s_frac * (p.Delta_i + p.delta_i_sq) \
        - p.delta_bar_p
    term2 = (2.0 + p.L) * straggler_pen / denom
    return jnp.where(denom > 0, term1 + term2, jnp.inf)
