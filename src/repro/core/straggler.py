"""Straggler schedules (Sec. 2.4, 6.1.2).

A schedule is a boolean array ``[rounds, n]`` with True = submitted in time.
Permanent stragglers stop submitting after ``stop_round`` (paper: round 40);
temporary stragglers miss individual rounds but return the next round.

Schedules are sampled host-side with numpy (they model external network
conditions, not traced computation) and fed to the jitted steps as arrays.
"""
from __future__ import annotations

import numpy as np


def no_stragglers(rounds: int, n: int) -> np.ndarray:
    return np.ones((rounds, n), dtype=bool)


def permanent(rounds: int, n: int, n_stragglers: int, stop_round: int = 40,
              seed: int = 0) -> np.ndarray:
    """``n_stragglers`` participants never submit again after ``stop_round``."""
    rng = np.random.default_rng(seed)
    mask = np.ones((rounds, n), dtype=bool)
    idx = rng.choice(n, size=min(n_stragglers, n), replace=False)
    mask[stop_round:, idx] = False
    return mask


def temporary(rounds: int, n: int, n_stragglers: int, miss_prob: float = 0.5,
              seed: int = 0, cold_boot_rounds: int = 2) -> np.ndarray:
    """``n_stragglers`` participants each miss random single rounds.

    A missed round is always followed by a submitted round (the paper's
    temporary stragglers "continue to submit in the next round after the
    missing round").  Cold-boot rounds are never missed (Alg. 1 assumes all
    devices submit during T_c).
    """
    rng = np.random.default_rng(seed)
    mask = np.ones((rounds, n), dtype=bool)
    idx = rng.choice(n, size=min(n_stragglers, n), replace=False)
    for i in idx:
        r = cold_boot_rounds
        while r < rounds:
            if rng.random() < miss_prob:
                mask[r, i] = False
                r += 2  # forced return next round
            else:
                r += 1
    return mask


def stack_ragged(schedules: list[np.ndarray], j_max: int | None = None,
                 n_max: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-edge ragged schedules into one dense device-layer tensor.

    ``schedules``: per-edge boolean arrays ``[rounds, J_e]`` (the output of
    ``from_fraction`` per edge).  Returns ``(dense, valid)`` where ``dense``
    is ``[rounds, N, J_max]`` with padded slots False (always-straggling —
    they carry zero aggregation weight anyway) and ``valid`` is ``[N, J_max]``
    marking real device slots.  This is the layout the jitted engine consumes:
    one gather instead of N ragged slices per round.

    ``j_max`` / ``n_max`` pad the device and edge dimensions past this
    deployment's own extents — the sweep fabric stacks grids whose points
    disagree on topology by padding every point to the grid maximum.  A
    padded edge is a fully-invalid row: all its slots read False in both
    ``dense`` and ``valid``, so it carries zero aggregation weight
    everywhere downstream.
    """
    rounds = schedules[0].shape[0]
    if any(s.shape[0] != rounds for s in schedules):
        raise ValueError("all per-edge schedules need the same round count")
    n = n_max if n_max is not None else len(schedules)
    if len(schedules) > n:
        raise ValueError(f"{len(schedules)} edges > n_max={n}")
    jm = j_max if j_max is not None else max(s.shape[1] for s in schedules)
    dense = np.zeros((rounds, n, jm), dtype=bool)
    valid = np.zeros((n, jm), dtype=bool)
    for e, sched in enumerate(schedules):
        je = sched.shape[1]
        if je > jm:
            raise ValueError(f"edge {e} has {je} devices > j_max={jm}")
        dense[:, e, :je] = sched
        valid[e, :je] = True
    return dense, valid


def from_fraction(rounds: int, n: int, frac: float, kind: str = "temporary",
                  **kw) -> np.ndarray:
    """Paper basic setting: 20% stragglers per layer -> n_stragglers = frac*n."""
    k = int(round(frac * n))
    if kind == "permanent":
        return permanent(rounds, n, k, **kw)
    if kind == "temporary":
        return temporary(rounds, n, k, **kw)
    if kind == "none":
        return no_stragglers(rounds, n)
    raise ValueError(f"unknown straggler kind: {kind}")
