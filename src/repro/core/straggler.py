"""Straggler schedules (Sec. 2.4, 6.1.2).

A schedule is a boolean array ``[rounds, n]`` with True = submitted in time.
Permanent stragglers stop submitting after ``stop_round`` (paper: round 40);
temporary stragglers miss individual rounds but return the next round.

Schedules are sampled host-side with numpy (they model external network
conditions, not traced computation) and fed to the jitted steps as arrays.
"""
from __future__ import annotations

import numpy as np


def no_stragglers(rounds: int, n: int) -> np.ndarray:
    return np.ones((rounds, n), dtype=bool)


def permanent(rounds: int, n: int, n_stragglers: int, stop_round: int = 40,
              seed: int = 0) -> np.ndarray:
    """``n_stragglers`` participants never submit again after ``stop_round``."""
    rng = np.random.default_rng(seed)
    mask = np.ones((rounds, n), dtype=bool)
    idx = rng.choice(n, size=min(n_stragglers, n), replace=False)
    mask[stop_round:, idx] = False
    return mask


def temporary(rounds: int, n: int, n_stragglers: int, miss_prob: float = 0.5,
              seed: int = 0, cold_boot_rounds: int = 2) -> np.ndarray:
    """``n_stragglers`` participants each miss random single rounds.

    A missed round is always followed by a submitted round (the paper's
    temporary stragglers "continue to submit in the next round after the
    missing round").  Cold-boot rounds are never missed (Alg. 1 assumes all
    devices submit during T_c).
    """
    rng = np.random.default_rng(seed)
    mask = np.ones((rounds, n), dtype=bool)
    idx = rng.choice(n, size=min(n_stragglers, n), replace=False)
    for i in idx:
        r = cold_boot_rounds
        while r < rounds:
            if rng.random() < miss_prob:
                mask[r, i] = False
                r += 2  # forced return next round
            else:
                r += 1
    return mask


def from_fraction(rounds: int, n: int, frac: float, kind: str = "temporary",
                  **kw) -> np.ndarray:
    """Paper basic setting: 20% stragglers per layer -> n_stragglers = frac*n."""
    k = int(round(frac * n))
    if kind == "permanent":
        return permanent(rounds, n, k, **kw)
    if kind == "temporary":
        return temporary(rounds, n, k, **kw)
    if kind == "none":
        return no_stragglers(rounds, n)
    raise ValueError(f"unknown straggler kind: {kind}")
