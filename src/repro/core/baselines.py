"""Benchmark aggregation methods from Sec. 6.1.6.

  * ``fedavg``   — plain (weighted) mean of all submissions; with a full mask
                   this is the W/O-Stragglers oracle.
  * ``t_fedavg`` — only timely submissions are averaged (stragglers dropped).
  * ``d_fedavg`` — stragglers represented by their last submitted weights,
                   verbatim (no delta extrapolation, no decay).

All share HieAvg's stacked-pytree convention so the simulator can swap them.
``d_fedavg`` keeps a plain last-weights store (reusing ``History.prev_w``).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .hieavg import History, _bshape, init_history  # noqa: F401

PyTree = Any


def _weighted_mean(stacked_w: PyTree, coef: jnp.ndarray) -> PyTree:
    coef = coef / jnp.maximum(jnp.sum(coef), 1e-12)
    return jax.tree.map(
        lambda w: jnp.sum(_bshape(coef, w) * w, axis=0), stacked_w)


@jax.jit
def fedavg(stacked_w: PyTree, part_weights: Optional[jnp.ndarray] = None) -> PyTree:
    leaves = jax.tree_util.tree_leaves(stacked_w)
    n = leaves[0].shape[0]
    if part_weights is None:
        part_weights = jnp.ones((n,), jnp.float32)
    return _weighted_mean(stacked_w, part_weights)


@jax.jit
def t_fedavg(stacked_w: PyTree, mask: jnp.ndarray,
             part_weights: Optional[jnp.ndarray] = None) -> PyTree:
    """Timely-only FedAvg: renormalized over present participants."""
    m = mask.astype(jnp.float32)
    if part_weights is None:
        part_weights = jnp.ones_like(m)
    return _weighted_mean(stacked_w, part_weights * m)


@jax.jit
def d_fedavg(stacked_w: PyTree, mask: jnp.ndarray, last_w: PyTree,
             part_weights: Optional[jnp.ndarray] = None
             ) -> tuple[PyTree, PyTree]:
    """Delayed-weights FedAvg: straggler slots filled with last submissions.

    Returns (aggregate, updated last_w store).
    """
    m = mask.astype(jnp.float32)
    if part_weights is None:
        part_weights = jnp.ones_like(m)

    def fill(w, lw):
        mb = _bshape(m, w)
        return mb * w + (1.0 - mb) * lw

    filled = jax.tree.map(fill, stacked_w, last_w)
    new_last = filled  # present -> current weights; absent -> unchanged
    return _weighted_mean(filled, part_weights), new_last
