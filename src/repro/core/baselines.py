"""Benchmark aggregation methods from Sec. 6.1.6.

  * ``fedavg``   — plain (weighted) mean of all submissions; with a full mask
                   this is the W/O-Stragglers oracle.
  * ``t_fedavg`` — only timely submissions are averaged (stragglers dropped).
  * ``d_fedavg`` — stragglers represented by their last submitted weights,
                   verbatim (no delta extrapolation, no decay).
  * ``delayed_grad`` — stragglers' round-t updates arrive one round late and
                   are mixed in with a staleness-discounted weight
                   ("Stragglers Are Not Disaster", arXiv:2102.06329,
                   adapted to the weight-averaging convention here).

All share HieAvg's stacked-pytree convention so the simulator can swap them.
``d_fedavg`` keeps a plain last-weights store (reusing ``History.prev_w``);
``delayed_grad`` keeps a (pending weights, staleness age) pair.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .hieavg import History, _bshape, init_history  # noqa: F401

PyTree = Any


def _weighted_mean(stacked_w: PyTree, coef: jnp.ndarray) -> PyTree:
    coef = coef / jnp.maximum(jnp.sum(coef), 1e-12)
    return jax.tree.map(
        lambda w: jnp.sum(_bshape(coef, w) * w, axis=0), stacked_w)


@jax.jit
def fedavg(stacked_w: PyTree, part_weights: Optional[jnp.ndarray] = None) -> PyTree:
    leaves = jax.tree_util.tree_leaves(stacked_w)
    n = leaves[0].shape[0]
    if part_weights is None:
        part_weights = jnp.ones((n,), jnp.float32)
    return _weighted_mean(stacked_w, part_weights)


@jax.jit
def t_fedavg(stacked_w: PyTree, mask: jnp.ndarray,
             part_weights: Optional[jnp.ndarray] = None) -> PyTree:
    """Timely-only FedAvg: renormalized over present participants."""
    m = mask.astype(jnp.float32)
    if part_weights is None:
        part_weights = jnp.ones_like(m)
    return _weighted_mean(stacked_w, part_weights * m)


@jax.jit
def d_fedavg(stacked_w: PyTree, mask: jnp.ndarray, last_w: PyTree,
             part_weights: Optional[jnp.ndarray] = None
             ) -> tuple[PyTree, PyTree]:
    """Delayed-weights FedAvg: straggler slots filled with last submissions.

    Returns (aggregate, updated last_w store).
    """
    m = mask.astype(jnp.float32)
    if part_weights is None:
        part_weights = jnp.ones_like(m)

    def fill(w, lw):
        mb = _bshape(m, w)
        return mb * w + (1.0 - mb) * lw

    filled = jax.tree.map(fill, stacked_w, last_w)
    new_last = filled  # present -> current weights; absent -> unchanged
    return _weighted_mean(filled, part_weights), new_last


@jax.jit
def delayed_grad(stacked_w: PyTree, mask: jnp.ndarray, pending: PyTree,
                 age: jnp.ndarray, beta, delta,
                 part_weights: Optional[jnp.ndarray] = None
                 ) -> tuple[PyTree, PyTree, jnp.ndarray]:
    """Delayed-gradient aggregation with staleness-discounted weights.

    Per "Stragglers Are Not Disaster" (arXiv:2102.06329), adapted to this
    repo's weight-averaging convention: a straggler's round-t update is
    not dropped — it arrives one aggregation round late (``pending`` holds
    the last update that DID arrive) and is mixed in with the discounted
    coefficient ``beta ** k'``, where ``k'`` is the number of consecutive
    missed rounds including this one (``k' = age + 1``; ``age`` counts
    prior consecutive misses).  Slots stale past ``delta`` consecutive
    rounds (``k' > delta``) are dropped entirely (coefficient 0).

    The aggregate renormalizes over the effective coefficients
    (``_weighted_mean``), matching the other baselines here.

    Returns ``(aggregate, new_pending, new_age)``:
      * ``new_pending = stacked_w`` — every participant's current update is
        in flight and arrives by the next aggregation round (present
        participants' updates arrived *now*, which is the same store);
      * ``new_age`` — 0 where present, ``age + 1`` where missing.

    ``beta``/``delta`` may be traced scalars (they are batched sweep
    fields in the engine).  First-round semantics (treat everyone as
    present — there is nothing to be stale against) are the caller's job,
    exactly like ``d_fedavg``.
    """
    m = mask.astype(jnp.float32)
    if part_weights is None:
        part_weights = jnp.ones_like(m)
    k_prime = age + 1.0
    stale_c = (beta ** k_prime) * (k_prime <= delta).astype(jnp.float32)
    coef = part_weights * (m + (1.0 - m) * stale_c)

    def fill(w, p):
        mb = _bshape(m, w)
        return mb * w + (1.0 - mb) * p

    filled = jax.tree.map(fill, stacked_w, pending)
    new_age = (age + 1.0) * (1.0 - m)
    return _weighted_mean(filled, coef), stacked_w, new_age
