"""Latency model and K* optimization (Sec. 5).

Communication uses Shannon capacity r = B log2(1 + u*pi/eps^2); transmission
latency is D/r.  Compute latency is C/f (CPU cycles / clock).  Total latency
(Sec. 5.1.4, simplified form):

    L ~= T*N*J*K*(2*E[LM] + E[LP]) + 2*T*N*E[LM']

The optimization (Sec. 5.2) picks the number of edge rounds K minimizing L
subject to
    C1: Omega(K) <= Omega_bar      (convergence bound, Thm 2 RHS)
    C2: L_bc     <= L_g(K)         (consensus hidden inside the edge window)
    C3: K in N+.

This is an integer program over a single scalar; we solve it exactly by
enumeration (the paper suggests CVXPY — unavailable offline, and enumeration
over K <= K_max is already polynomial and exact).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np


def shannon_rate(bandwidth_hz: float, tx_power: float, channel_gain: float,
                 noise: float) -> float:
    """r = B log2(1 + u*pi / eps^2)  [bits/s]."""
    return bandwidth_hz * math.log2(1.0 + tx_power * channel_gain / noise ** 2)


def comm_latency(model_bytes: float, rate_bps: float) -> float:
    """LM = D / r (D in bits)."""
    return model_bytes * 8.0 / rate_bps


def compute_latency(cpu_cycles: float, clock_hz: float) -> float:
    """LP = C / f."""
    return cpu_cycles / clock_hz


@dataclasses.dataclass
class LatencyParams:
    """Expectation-level parameters of Sec. 5.1 (defaults = the paper's
    measured numbers: 1.67 s local training, 0.51 s device<->edge transfer,
    0.05 s edge<->edge link, Sec. 6.2.2)."""
    T: int = 50            # global rounds
    N: int = 5             # edge servers
    J: int = 5             # devices per edge
    lm_device: float = 0.51   # E[LM]   device<->edge one-way
    lp_device: float = 1.67   # E[LP]   local training per edge round
    lm_edge: float = 0.05     # E[LM']  edge<->leader one-way


def total_latency(K: int, p: LatencyParams) -> float:
    """L(K) — Sec. 5.1.4 simplified expectation form."""
    local = p.T * p.N * p.J * K * (2.0 * p.lm_device + p.lp_device)
    edge = 2.0 * p.T * p.N * p.lm_edge
    return local + edge


def edge_window(K: int, p: LatencyParams) -> float:
    """L_g = K * max(LM + LP): time the blockchain has to finish consensus."""
    return K * (p.lm_device + p.lp_device)


@dataclasses.dataclass
class KOptResult:
    k_star: int
    latency: float
    feasible: np.ndarray     # [K_max] bool
    latencies: np.ndarray    # [K_max]
    omegas: np.ndarray       # [K_max]


def optimize_k(p: LatencyParams, omega_fn: Callable[[int], float],
               omega_bar: float, consensus_latency: float,
               k_max: int = 64) -> Optional[KOptResult]:
    """argmin_K L(K)  s.t.  Omega(K) <= Omega_bar, L_bc <= L_g(K), K >= 1.

    Returns None when infeasible for every K <= k_max.
    L(K) is increasing in K while Omega(K) decreases (Corollary 1), so K* is
    the smallest feasible K — but we enumerate anyway for robustness to
    non-monotone omega_fn.
    """
    ks = np.arange(1, k_max + 1)
    lat = np.array([total_latency(int(k), p) for k in ks])
    om = np.array([omega_fn(int(k)) for k in ks])
    win = np.array([edge_window(int(k), p) for k in ks])
    feas = (om <= omega_bar) & (consensus_latency <= win)
    if not feas.any():
        return None
    idx = int(np.argmin(np.where(feas, lat, np.inf)))
    return KOptResult(k_star=int(ks[idx]), latency=float(lat[idx]),
                      feasible=feas, latencies=lat, omegas=om)
