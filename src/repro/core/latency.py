"""Latency model and K* optimization (Sec. 5) — the latency fabric core.

Communication uses Shannon capacity r = B log2(1 + u*pi/eps^2); transmission
latency is D/r.  Compute latency is C/f (CPU cycles / clock).  Total latency
(Sec. 5.1.4, simplified form):

    L ~= T*N*J*K*(2*E[LM] + E[LP]) + 2*T*N*E[LM']

The optimization (Sec. 5.2) picks the number of edge rounds K minimizing L
subject to
    C1: Omega(K) <= Omega_bar      (convergence bound, Thm 2 RHS)
    C2: L_bc     <= L_g(K)         (consensus hidden inside the edge window)
    C3: K in N+.

This is an integer program over a single scalar; we solve it exactly by
enumeration over a dense ``[K_max]`` axis.  Two implementations share the
same masked-argmin semantics:

  * ``optimize_k`` — the host-side float64 reference (returns
    ``KOptResult``/``None``), used by the analytic callers and as the
    parity anchor;
  * ``total_latency_k``/``edge_window_k`` + ``optimize_k_masked`` — the
    traced ``jnp`` path: everything is an array over the dense K axis, the
    argmin is masked by the feasibility constraints, and the whole thing
    is jit/vmap-friendly so the sweep fabric can batch K* solves over
    parameter grids (one call per grid, not per point).

``LatencyParams`` additionally carries the dispersion knobs of the
engine's per-round accounting (``repro.fl.engine`` draws per-device
compute/comm times from it; see ``build_inputs``): jitter widths, the
straggler slowdown, and the deadline multiplier of the deadline-based
aggregation the paper assumes.

How this module relates to the engine's *empirical* clock (the "latency
plane" of ``EngineInputs``): the expectation model here answers "what does
Sec. 5 predict", while ``build_inputs`` draws concrete per-device round
times from the same ``LatencyParams`` (stragglers delayed, deadline
capped) and ``run_engine`` threads the resulting simulated clock through
its scan — so every sweep reports a theoretical ``optimize_k`` K* next to
a measured ``SweepResult.k_star_empirical`` one.  The full contract —
which draws live where, what padding zeroes, what the clock charges per
round — is documented in docs/ARCHITECTURE.md (§Latency plane).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np


def shannon_rate(bandwidth_hz: float, tx_power: float, channel_gain: float,
                 noise: float) -> float:
    """r = B log2(1 + u*pi / eps^2)  [bits/s]."""
    return bandwidth_hz * math.log2(1.0 + tx_power * channel_gain / noise ** 2)


def comm_latency(model_bytes: float, rate_bps: float) -> float:
    """LM = D / r (D in bits)."""
    return model_bytes * 8.0 / rate_bps


def compute_latency(cpu_cycles: float, clock_hz: float) -> float:
    """LP = C / f."""
    return cpu_cycles / clock_hz


@dataclasses.dataclass
class LatencyParams:
    """Expectation-level parameters of Sec. 5.1 (defaults = the paper's
    measured numbers: 1.67 s local training, 0.51 s device<->edge transfer,
    0.05 s edge<->edge link, Sec. 6.2.2) plus the dispersion knobs the
    engine's per-round accounting draws from."""
    T: int = 50            # global rounds
    N: int = 5             # edge servers
    J: int = 5             # devices per edge
    lm_device: float = 0.51   # E[LM]   device<->edge one-way
    lp_device: float = 1.67   # E[LP]   local training per edge round
    lm_edge: float = 0.05     # E[LM']  edge<->leader one-way
    # --- per-round accounting (engine path; the expectation model above
    # ignores these).  A device's round draw is
    #   2*lm_device*U(1±lm_jitter) + lp_device*U(1±lp_jitter),
    # a straggler's submission is delayed by ``straggler_slowdown`` and
    # the edge proceeds at the deadline ``deadline_mult * (2 lm + lp)``
    # without it (deadline-based aggregation, Sec. 2.4).
    lm_jitter: float = 0.08
    lp_jitter: float = 0.08
    straggler_slowdown: float = 2.5
    deadline_mult: float = 1.5
    # Optional per-device clock-rate multipliers, shape [D] (D = total
    # devices): a heterogeneous fleet where device d's round draw is
    # scaled by ``rate_mult[d]`` every round, instead of iid draws around
    # the one shared expectation.  ``None`` = homogeneous (the default).
    # In population mode the per-round occupant's ``time_scale`` plays
    # this role instead (drawn from the population store per cohort).
    # The expectation-level model above intentionally ignores it.
    rate_mult: Optional[np.ndarray] = None


def round_time(p: LatencyParams) -> float:
    """Expected single edge-round time per device: 2 E[LM] + E[LP]."""
    return 2.0 * p.lm_device + p.lp_device


def device_deadline(p: LatencyParams) -> float:
    """The edge's per-round submission deadline (Sec. 2.4 deadline-based
    system): stragglers whose delayed submission misses it are dropped and
    the round closes at the deadline."""
    return p.deadline_mult * round_time(p)


# ----------------------------------------------------- scalar reference
def total_latency(K: int, p: LatencyParams) -> float:
    """L(K) — Sec. 5.1.4 simplified expectation form (float64 reference)."""
    local = p.T * p.N * p.J * K * (2.0 * p.lm_device + p.lp_device)
    edge = 2.0 * p.T * p.N * p.lm_edge
    return local + edge


def edge_window(K: int, p: LatencyParams) -> float:
    """L_g = K * max(LM + LP): time the blockchain has to finish consensus."""
    return K * (p.lm_device + p.lp_device)


# ---------------------------------------------------- dense traced model
def k_axis(k_max: int) -> jnp.ndarray:
    """The dense K enumeration axis: [1, 2, ..., k_max] as f32."""
    return jnp.arange(1, k_max + 1, dtype=jnp.float32)


def total_latency_k(p: LatencyParams, k_max: int) -> jnp.ndarray:
    """L(K) over the dense K axis — ``[k_max]`` f32, traced.

    Fields of ``p`` may be traced scalars (vmap over
    ``dataclasses.replace``'d params batches K* solves over a grid).
    """
    ks = k_axis(k_max)
    local = p.T * p.N * p.J * ks * (2.0 * p.lm_device + p.lp_device)
    return local + 2.0 * p.T * p.N * p.lm_edge


def edge_window_k(p: LatencyParams, k_max: int) -> jnp.ndarray:
    """L_g(K) over the dense K axis — ``[k_max]`` f32, traced."""
    return k_axis(k_max) * (p.lm_device + p.lp_device)


def optimize_k_masked(latencies: jnp.ndarray, omegas: jnp.ndarray,
                      windows: jnp.ndarray, omega_bar, consensus_latency
                      ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Masked-argmin K* solve over dense ``[K_max]`` arrays (traced).

    Returns ``(k_star, latency, feasible)`` where ``k_star`` is an i32
    scalar (-1 when no K is feasible, in which case ``latency`` is +inf).
    Pure ``jnp`` on same-shape arrays — jit and vmap compose, so a whole
    grid of (params, omega_bar, L_bc) solves is one batched call.
    """
    feas = (omegas <= omega_bar) & (consensus_latency <= windows)
    lat = jnp.where(feas, latencies, jnp.inf)
    idx = jnp.argmin(lat)
    any_f = jnp.any(feas)
    k_star = jnp.where(any_f, idx + 1, -1).astype(jnp.int32)
    return k_star, jnp.where(any_f, lat[idx], jnp.inf), feas


# -------------------------------------------------------- host optimizer
@dataclasses.dataclass
class KOptResult:
    k_star: int
    latency: float
    feasible: np.ndarray     # [K_max] bool
    latencies: np.ndarray    # [K_max]
    omegas: np.ndarray       # [K_max]


def optimize_k(p: LatencyParams, omega_fn: Callable[[int], float],
               omega_bar: float, consensus_latency: float,
               k_max: int = 64) -> Optional[KOptResult]:
    """argmin_K L(K)  s.t.  Omega(K) <= Omega_bar, L_bc <= L_g(K), K >= 1.

    Returns None when infeasible for every K <= k_max.
    L(K) is increasing in K while Omega(K) decreases (Corollary 1), so K* is
    the smallest feasible K — but we enumerate anyway for robustness to
    non-monotone omega_fn.  ``tests/test_latency_fabric.py`` pins this
    float64 reference against the traced dense path above on a K <= 64
    enumeration.
    """
    if int(k_max) != k_max or k_max < 1:
        raise ValueError(f"optimize_k: k_max must be a positive integer, "
                         f"got {k_max!r}")
    k_max = int(k_max)
    if not np.isfinite(omega_bar):
        raise ValueError(f"optimize_k: omega_bar must be finite, got "
                         f"{omega_bar!r} — an infinite/NaN bound makes "
                         "constraint C1 vacuous or unsatisfiable")
    if not np.isfinite(consensus_latency) or consensus_latency < 0:
        raise ValueError(f"optimize_k: consensus_latency must be finite "
                         f"and >= 0, got {consensus_latency!r}")
    ks = np.arange(1, k_max + 1)
    lat = np.array([total_latency(int(k), p) for k in ks])
    om = np.array([omega_fn(int(k)) for k in ks])
    win = np.array([edge_window(int(k), p) for k in ks])
    feas = (om <= omega_bar) & (consensus_latency <= win)
    if not feas.any():
        return None
    idx = int(np.argmin(np.where(feas, lat, np.inf)))
    return KOptResult(k_star=int(ks[idx]), latency=float(lat[idx]),
                      feasible=feas, latencies=lat, omegas=om)
