"""Core library: the paper's contribution (HieAvg, stragglers, Raft, latency)."""
from .hieavg import (History, init_history, update_history, edge_aggregate,
                     global_aggregate, edge_aggregate_cold,
                     global_aggregate_cold)
from .baselines import fedavg, t_fedavg, d_fedavg, delayed_grad
from .rng import STREAMS, stream_rng, stream_seed, stream_seq
from .straggler import no_stragglers, permanent, temporary, from_fraction
from .blockchain import (Block, ConsensusChain, RaftChain, RaftParams,
                         expected_consensus_energy,
                         expected_consensus_latency,
                         expected_election_latency)
from .consensus import (CONSENSUS_MODELS, ConsensusSpec, PoFELChain,
                        PoFELParams, ShardedChain, ShardedParams, make_chain,
                        expected_pofel_energy, expected_pofel_latency,
                        expected_round_energy, expected_round_latency,
                        expected_sharded_energy, expected_sharded_latency)
from .latency import (LatencyParams, shannon_rate, comm_latency,
                      compute_latency, total_latency, edge_window, optimize_k,
                      KOptResult, k_axis, total_latency_k, edge_window_k,
                      optimize_k_masked, round_time, device_deadline)
from .convergence import BoundParams, omega_bound, omega_bound_k

__all__ = [
    "History", "init_history", "update_history", "edge_aggregate",
    "global_aggregate", "edge_aggregate_cold", "global_aggregate_cold",
    "fedavg", "t_fedavg", "d_fedavg", "delayed_grad",
    "STREAMS", "stream_rng", "stream_seed", "stream_seq",
    "no_stragglers", "permanent", "temporary", "from_fraction",
    "Block", "ConsensusChain", "RaftChain", "RaftParams",
    "expected_consensus_energy", "expected_consensus_latency",
    "expected_election_latency",
    "CONSENSUS_MODELS", "ConsensusSpec", "PoFELChain", "PoFELParams",
    "ShardedChain", "ShardedParams", "make_chain",
    "expected_pofel_energy", "expected_pofel_latency",
    "expected_round_energy", "expected_round_latency",
    "expected_sharded_energy", "expected_sharded_latency",
    "LatencyParams", "shannon_rate", "comm_latency", "compute_latency",
    "total_latency", "edge_window", "optimize_k", "KOptResult",
    "k_axis", "total_latency_k", "edge_window_k", "optimize_k_masked",
    "round_time", "device_deadline",
    "BoundParams", "omega_bound", "omega_bound_k",
]
