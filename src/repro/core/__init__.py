"""Core library: the paper's contribution (HieAvg, stragglers, Raft, latency)."""
from .hieavg import (History, init_history, update_history, edge_aggregate,
                     global_aggregate, edge_aggregate_cold,
                     global_aggregate_cold)
from .baselines import fedavg, t_fedavg, d_fedavg
from .straggler import no_stragglers, permanent, temporary, from_fraction
from .blockchain import Block, RaftChain, RaftParams
from .latency import (LatencyParams, shannon_rate, comm_latency,
                      compute_latency, total_latency, edge_window, optimize_k,
                      KOptResult)
from .convergence import BoundParams, omega_bound

__all__ = [
    "History", "init_history", "update_history", "edge_aggregate",
    "global_aggregate", "edge_aggregate_cold", "global_aggregate_cold",
    "fedavg", "t_fedavg", "d_fedavg",
    "no_stragglers", "permanent", "temporary", "from_fraction",
    "Block", "RaftChain", "RaftParams",
    "LatencyParams", "shannon_rate", "comm_latency", "compute_latency",
    "total_latency", "edge_window", "optimize_k", "KOptResult",
    "BoundParams", "omega_bound",
]
