"""Consensus zoo — pluggable consensus models for the consortium chain.

The paper fixes Raft as the consortium-chain consensus and optimizes the
round latency around its delay; production BHFL would sweep the protocol
like any other axis.  This module makes that possible: a *consensus model*
is a pair of

  * a discrete-event Monte-Carlo replay — a ``ConsensusChain`` subclass
    (``core.blockchain``) driven once per global round as
    ``elect_leader()`` → ``commit_block()``, each returning elapsed
    simulated seconds and accruing Joules on ``.energy``, raising (never
    spinning) below quorum, and
  * closed-form expected per-round latency AND energy models, pinned ≤5%
    against the replay by hypothesis-driven Monte-Carlo tests
    (tests/test_consensus_zoo.py, ``pytest -m consensus_mc``).

Protocols:

  raft     The paper's consortium Raft (``core.blockchain.RaftChain``).
           Energy = message counting (RequestVote/AppendEntries fan-outs
           + replies) × ``e_msg``.

  pofel    PoFEL-style Proof-of-Federated-Learning (arXiv:2308.07840):
           instead of hash mining, every alive node *scores* the round's
           candidate models (``n_candidates × eval_time`` seconds each,
           jittered); the best-scoring candidate's proposer wins, a vote
           round trip and block commit follow.  Energy = scoring watts ×
           total scoring seconds + messages — the protocol's point is
           that useful evaluation replaces wasted hashing.

  sharded  Layered/sharded FL chain (arXiv:2104.13130): nodes partition
           round-robin into ``n_shards`` committees; each shard finalizes
           its sub-block in parallel (a jittered 3-phase intra-shard
           round), the round closes on the *slowest* shard plus one
           cross-shard final commit.  Quorum is PER SHARD — every shard
           must hold an intra-shard majority or the model raises, just
           like Raft below global majority.

The engine consumes any model identically: the chain is replayed host-side
before the jitted run (``fl.engine.replay_chain``) into the per-round
``cons_time``/``cons_energy`` planes, so ``consensus=`` is a *data-batched*
sweep field — mixed-consensus × straggler × K grids compile as ONE padded
call (``fl.sweep.BATCHED_FIELDS``).  ``consensus_mult`` scales any
protocol's latency draws; energy is never scaled by it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from .blockchain import (Block, ConsensusChain, RaftChain, RaftParams,
                         expected_consensus_energy,
                         expected_consensus_latency)


# ------------------------------------------------------------------- PoFEL
@dataclasses.dataclass
class PoFELParams:
    """PoFEL-style consensus timing/energy parameters.

    Per round, each alive node scores ``n_candidates`` candidate models at
    ``eval_time`` seconds each (uniform ±``eval_jitter`` node-to-node);
    the committee waits for the slowest scorer, then a vote round trip and
    the block commit close the round.  ``eval_power`` is the Watts a node
    draws while scoring; ``e_msg`` the Joules per protocol message.
    """
    link_latency: float = 0.05
    block_serialize: float = 0.01
    eval_time: float = 0.08       # seconds to score ONE candidate model
    eval_jitter: float = 0.3      # node time ~ c·eval_time·U(1±jitter)
    n_candidates: int = 3         # candidate models scored per round
    eval_power: float = 2.0       # W drawn while scoring
    e_msg: float = 0.05           # J per protocol message


class PoFELChain(ConsensusChain):
    """Proof-of-Federated-Learning committee (arXiv:2308.07840 style)."""

    def __init__(self, n_nodes: int, params: Optional[PoFELParams] = None,
                 seed: int = 0):
        super().__init__(n_nodes, seed)
        self.params = params or PoFELParams()

    def elect_leader(self) -> tuple[int, float]:
        """Candidate-scoring phase: every alive node evaluates the round's
        candidates; the fastest scorer's pick leads.  Elapsed = slowest
        scorer + vote round trip.  Energy = scoring watt-seconds + the
        ``2·(A-1)`` vote messages."""
        a = self._require_majority()
        alive_ids = np.flatnonzero(self.alive)
        p = self.params
        draws = (p.n_candidates * p.eval_time
                 * self.rng.uniform(1.0 - p.eval_jitter,
                                    1.0 + p.eval_jitter, a))
        elapsed = float(draws.max()) + 2.0 * p.link_latency
        self.energy += (p.eval_power * float(draws.sum())
                        + 2.0 * (a - 1) * p.e_msg)
        self.term += 1
        self.leader = int(alive_ids[int(draws.argmin())])
        self.clock += elapsed
        return self.leader, elapsed

    def commit_block(self, edge_models_digest: Any, global_model_digest: Any
                     ) -> tuple[Block, float]:
        """Winner packages + broadcasts the block; finalized on majority
        ack (serialize + round trip, ``2·(A-1)`` messages)."""
        elapsed = 0.0
        if self.leader is None or not self.alive[self.leader]:
            _, t = self.elect_leader()
            elapsed += t
        a = self._require_majority()
        p = self.params
        payload = {"edges": edge_models_digest, "global": global_model_digest,
                   "term": self.term}
        elapsed += p.block_serialize + 2.0 * p.link_latency
        self.energy += 2.0 * (a - 1) * p.e_msg
        block = self._append_block(payload, elapsed)
        return block, elapsed


def expected_pofel_latency(params: PoFELParams, n_nodes: int,
                           n_alive: Optional[int] = None) -> float:
    """E[elapsed] of one PoFEL elect+commit round.

    The scoring phase is the max of A iid U(lo, hi) node times with
    ``lo = c·et·(1-j)``, ``hi = c·et·(1+j)``: ``E[max] = lo + w·A/(A+1)``.
    Add the vote round trip and the commit (serialize + round trip).
    Returns ``inf`` below quorum (the chain raises there).
    """
    a = n_nodes if n_alive is None else n_alive
    if a < n_nodes // 2 + 1:
        return float("inf")
    ct = params.n_candidates * params.eval_time
    lo = ct * (1.0 - params.eval_jitter)
    w = 2.0 * ct * params.eval_jitter
    e_scoring = lo + w * a / (a + 1.0)
    return (e_scoring + 2.0 * params.link_latency
            + params.block_serialize + 2.0 * params.link_latency)


def expected_pofel_energy(params: PoFELParams, n_nodes: int,
                          n_alive: Optional[int] = None) -> float:
    """E[energy] of one PoFEL elect+commit round, in Joules.

    Scoring: A nodes × c candidates × E[eval_time] at ``eval_power`` Watts
    (the jitter is mean-1, so it drops out of the expectation).  Messages:
    ``2·(A-1)`` votes + ``2·(A-1)`` commit acks.
    """
    a = n_nodes if n_alive is None else n_alive
    if a < n_nodes // 2 + 1:
        return float("inf")
    scoring = params.eval_power * a * params.n_candidates * params.eval_time
    return scoring + 4.0 * (a - 1) * params.e_msg


# ----------------------------------------------------------------- sharded
@dataclasses.dataclass
class ShardedParams:
    """Sharded-chain consensus parameters (arXiv:2104.13130 style).

    Nodes partition round-robin into ``n_shards`` committees (capped at the
    node count); each shard runs a 3-phase intra-shard round of base cost
    ``block_serialize + 3·link_latency``, jittered uniform ±``intra_jitter``
    shard-to-shard.  The round closes on the slowest shard plus one
    cross-shard final commit (serialize + round trip).
    """
    link_latency: float = 0.05
    block_serialize: float = 0.01
    n_shards: int = 2
    intra_jitter: float = 0.3     # shard round time ~ base·U(1±jitter)
    e_msg: float = 0.05


def _shard_sizes(n_nodes: int, n_shards: int) -> np.ndarray:
    """Round-robin shard membership counts (node i → shard i % S)."""
    s = min(n_shards, n_nodes)
    return np.bincount(np.arange(n_nodes) % s, minlength=s)


class ShardedChain(ConsensusChain):
    """Parallel shard committees with a cross-shard final commit."""

    def __init__(self, n_nodes: int, params: Optional[ShardedParams] = None,
                 seed: int = 0):
        super().__init__(n_nodes, seed)
        self.params = params or ShardedParams()
        self.n_shards = min(self.params.n_shards, n_nodes)
        self.shard_of = np.arange(n_nodes) % self.n_shards

    def _shard_alive(self) -> np.ndarray:
        """Alive count per shard, [S]."""
        return np.bincount(self.shard_of[self.alive],
                           minlength=self.n_shards)

    def _require_shard_quorum(self) -> np.ndarray:
        """Every shard needs an intra-shard majority; returns alive-per-
        shard counts.  (Losing a global majority always breaks at least
        one shard's majority, so this is at least as strict as Raft's
        gate.)"""
        sizes = np.bincount(self.shard_of, minlength=self.n_shards)
        alive = self._shard_alive()
        for s in range(self.n_shards):
            if alive[s] < sizes[s] // 2 + 1:
                raise RuntimeError(
                    f"no majority alive in shard {s} "
                    f"({alive[s]}/{sizes[s]} nodes): the shard cannot "
                    "finalize its sub-block")
        return alive

    def elect_leader(self) -> tuple[int, float]:
        """Intra-shard phase: every shard finalizes its sub-block in
        parallel; the round waits for the slowest shard.  Energy = 3-phase
        fan-outs within every shard (``3·(a_s - 1)`` messages each)."""
        alive_s = self._require_shard_quorum()
        p = self.params
        base = p.block_serialize + 3.0 * p.link_latency
        draws = base * self.rng.uniform(1.0 - p.intra_jitter,
                                        1.0 + p.intra_jitter, self.n_shards)
        elapsed = float(draws.max())
        self.energy += p.e_msg * 3.0 * float(
            np.maximum(alive_s - 1, 0).sum())
        self.term += 1
        # cross-shard coordinator: deterministic — the lowest-id alive node
        self.leader = int(np.flatnonzero(self.alive)[0])
        self.clock += elapsed
        return self.leader, elapsed

    def commit_block(self, edge_models_digest: Any, global_model_digest: Any
                     ) -> tuple[Block, float]:
        """Cross-shard final commit: shard digests reach the coordinator,
        which serializes the final block and broadcasts it shard-to-shard
        (``2·(S-1)`` messages, deterministic latency)."""
        elapsed = 0.0
        if self.leader is None or not self.alive[self.leader]:
            _, t = self.elect_leader()
            elapsed += t
        self._require_shard_quorum()
        p = self.params
        payload = {"edges": edge_models_digest, "global": global_model_digest,
                   "term": self.term}
        elapsed += p.block_serialize + 2.0 * p.link_latency
        self.energy += p.e_msg * 2.0 * (self.n_shards - 1)
        block = self._append_block(payload, elapsed)
        return block, elapsed


def _prefix_shard_alive(n_nodes: int, n_alive: int, n_shards: int
                        ) -> np.ndarray:
    """Alive-per-shard counts when the alive set is the id prefix
    ``0..n_alive-1`` under round-robin assignment — the failure pattern
    the closed forms assume (and the MC pins use: fail the highest ids).
    For an arbitrary alive set, read the counts off the chain itself."""
    s = min(n_shards, n_nodes)
    return np.bincount(np.arange(n_alive) % s, minlength=s)


def expected_sharded_latency(params: ShardedParams, n_nodes: int,
                             n_alive: Optional[int] = None) -> float:
    """E[elapsed] of one sharded elect+commit round.

    Max of S iid ``base·U(1-j, 1+j)`` shard rounds:
    ``E[max] = base·(1 + j·(S-1)/(S+1))``; plus the deterministic
    cross-shard commit.  Latency does not depend on the alive count (only
    the per-shard quorum gates it); returns ``inf`` when the prefix
    alive-set assumption leaves any shard below majority.
    """
    a = n_nodes if n_alive is None else n_alive
    s = min(params.n_shards, n_nodes)
    sizes = _shard_sizes(n_nodes, params.n_shards)
    alive_s = _prefix_shard_alive(n_nodes, a, params.n_shards)
    if (alive_s < sizes // 2 + 1).any():
        return float("inf")
    base = params.block_serialize + 3.0 * params.link_latency
    e_max = base * (1.0 + params.intra_jitter * (s - 1.0) / (s + 1.0))
    return e_max + params.block_serialize + 2.0 * params.link_latency


def expected_sharded_energy(params: ShardedParams, n_nodes: int,
                            n_alive: Optional[int] = None) -> float:
    """E[energy] of one sharded elect+commit round (deterministic):
    3-phase fan-outs within every shard + the cross-shard broadcast,
    under the same prefix alive-set assumption as the latency form."""
    a = n_nodes if n_alive is None else n_alive
    s = min(params.n_shards, n_nodes)
    sizes = _shard_sizes(n_nodes, params.n_shards)
    alive_s = _prefix_shard_alive(n_nodes, a, params.n_shards)
    if (alive_s < sizes // 2 + 1).any():
        return float("inf")
    intra = 3.0 * float(np.maximum(alive_s - 1, 0).sum())
    return params.e_msg * (intra + 2.0 * (s - 1))


# ---------------------------------------------------------------- registry
@dataclasses.dataclass(frozen=True)
class ConsensusSpec:
    """One zoo entry: the MC replay + its closed-form latency/energy pair.

    ``make_params(link_latency, n_shards)`` builds the protocol's params
    from the deployment knobs a ``BHFLSetting`` carries (core never
    imports configs); ``expected_latency``/``expected_energy`` take
    ``(params, n_nodes, n_alive=None)`` and return ``inf`` below quorum.
    """
    name: str
    chain_cls: type
    params_cls: type
    make_params: Callable[[float, int], Any]
    expected_latency: Callable[..., float]
    expected_energy: Callable[..., float]


CONSENSUS_MODELS: dict[str, ConsensusSpec] = {
    "raft": ConsensusSpec(
        name="raft", chain_cls=RaftChain, params_cls=RaftParams,
        make_params=lambda link, n_shards: RaftParams(link_latency=link),
        expected_latency=expected_consensus_latency,
        expected_energy=expected_consensus_energy),
    "pofel": ConsensusSpec(
        name="pofel", chain_cls=PoFELChain, params_cls=PoFELParams,
        make_params=lambda link, n_shards: PoFELParams(link_latency=link),
        expected_latency=expected_pofel_latency,
        expected_energy=expected_pofel_energy),
    "sharded": ConsensusSpec(
        name="sharded", chain_cls=ShardedChain, params_cls=ShardedParams,
        make_params=lambda link, n_shards: ShardedParams(
            link_latency=link, n_shards=n_shards),
        expected_latency=expected_sharded_latency,
        expected_energy=expected_sharded_energy),
}


def _spec(name: str) -> ConsensusSpec:
    try:
        return CONSENSUS_MODELS[name]
    except KeyError:
        raise ValueError(
            f"unknown consensus model {name!r}; known models: "
            f"{sorted(CONSENSUS_MODELS)}") from None


def make_chain(name: str, n_nodes: int, *, link_latency: float = 0.05,
               n_shards: int = 2, seed: int = 0,
               params: Optional[Any] = None) -> ConsensusChain:
    """Build the named protocol's chain from deployment knobs.

    ``params`` overrides the knob-derived protocol params wholesale (must
    be the protocol's own params class); otherwise ``link_latency`` (all
    protocols) and ``n_shards`` (sharded only) parameterize the defaults.
    """
    spec = _spec(name)
    if params is None:
        params = spec.make_params(link_latency, n_shards)
    elif not isinstance(params, spec.params_cls):
        raise TypeError(
            f"consensus {name!r} takes {spec.params_cls.__name__} params, "
            f"got {type(params).__name__}")
    return spec.chain_cls(n_nodes, params, seed=seed)


def expected_round_latency(name: str, params: Any, n_nodes: int,
                           n_alive: Optional[int] = None) -> float:
    """The named protocol's closed-form E[per-round latency] (seconds)."""
    return _spec(name).expected_latency(params, n_nodes, n_alive)


def expected_round_energy(name: str, params: Any, n_nodes: int,
                          n_alive: Optional[int] = None) -> float:
    """The named protocol's closed-form E[per-round energy] (Joules)."""
    return _spec(name).expected_energy(params, n_nodes, n_alive)
