"""Numpy-based pytree checkpointing (atomic, step-indexed).

Layout: <dir>/step_<n>.npz with flattened key paths, plus a JSON sidecar of
auxiliary metadata.  Writes are atomic (tmp + rename) so a crashed writer
never corrupts the latest checkpoint — table stakes for FL training where
the blockchain log references model digests by round.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "|"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16.dtype:
            # npz cannot round-trip ml_dtypes; store the raw bits
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **_flatten(tree))
    os.replace(tmp, path)
    if metadata is not None:
        mpath = path.replace(".npz", ".json")
        # mkstemp like the npz write above: a fixed "<mpath>.tmp" name
        # lets two concurrent writers clobber each other's half-written
        # sidecar before either rename lands
        fd, mtmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(metadata, f)
        os.replace(mtmp, mpath)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: PyTree, step: Optional[int] = None
                       ) -> tuple[PyTree, Optional[dict]]:
    """Restore into the structure of ``like`` (dtypes/shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten(like)
    if set(data.files) != set(flat_like):
        missing = set(flat_like) - set(data.files)
        extra = set(data.files) - set(flat_like)
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_k, leaf in leaves_with_path[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_k)
        arr = data[key]
        want = np.asarray(leaf).dtype
        if want == jax.numpy.bfloat16.dtype and arr.dtype == np.uint16:
            arr = arr.view(want)          # reinterpret the stored bits
        if arr.shape != np.shape(leaf):
            raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
        restored.append(arr.astype(want))
    tree = jax.tree_util.tree_unflatten(leaves_with_path[1], restored)
    mpath = path.replace(".npz", ".json")
    meta = json.load(open(mpath)) if os.path.exists(mpath) else None
    return tree, meta
