from .engine import EngineInputs, build_inputs, run_engine
from .faults import FaultSchedule, FaultSpec, compile_schedule
from .population import DevicePopulation, PopulationSpec
from .simulator import BHFLSimulator, RunResult, run_comparison
from .sweep import (SweepBucket, SweepPlan, SweepResult, execute_plan,
                    plan_sweep, run_plan, run_sweep)

__all__ = ["BHFLSimulator", "RunResult", "run_comparison",
           "EngineInputs", "build_inputs", "run_engine",
           "FaultSpec", "FaultSchedule", "compile_schedule",
           "DevicePopulation", "PopulationSpec",
           "SweepBucket", "SweepPlan", "SweepResult", "execute_plan",
           "plan_sweep", "run_plan", "run_sweep"]
