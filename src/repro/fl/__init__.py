from .simulator import BHFLSimulator, RunResult, run_comparison

__all__ = ["BHFLSimulator", "RunResult", "run_comparison"]
