from .engine import (EngineInputs, SweepResult, build_inputs, run_engine,
                     run_sweep)
from .simulator import BHFLSimulator, RunResult, run_comparison

__all__ = ["BHFLSimulator", "RunResult", "run_comparison",
           "EngineInputs", "SweepResult", "build_inputs", "run_engine",
           "run_sweep"]
