"""Population-scale device plane: a store of [population] device profiles
from which each round gathers a sampled cohort ``[N, J_cohort]`` by index.

The paper's experiments cap at N × J of a few hundred devices because the
simulator materializes every device.  The ROADMAP north star is millions of
users — which requires decoupling the device *population* (who exists) from
the per-round *cohort* (who trains).  This module supplies the population
side:

  * ``DevicePopulation`` — a seed-major store of per-device profiles sized
    ``[population]``: the non-IID class assignment (its data shard — see
    ``data.partition.population_classes``), a per-device straggler
    propensity ``miss_prob`` (Beta-distributed around the spec mean, so the
    population is heterogeneous like a real fleet), and a per-device
    round-``time_scale`` multiplier (lognormal, mean 1; > 1 = slower
    device) feeding the latency fabric.
    These P-sized profile rows are the ONLY O(population) state anywhere;
    everything the engine touches is gathered per round.

  * cohort sampling — ``cohort_ids(T, n_edges, seed)`` draws the occupant
    of every device slot for every global round, with replacement, in
    O(T × cohort) work.  This extends the seed-deduped gather trick the
    sweep data plane already plays (gather rows by index instead of
    materializing copies): per-round randomness (straggler draws, batch
    sampling, latency jitter) is keyed by SLOT, and the occupant's profile
    is gathered into the slot — so device memory and per-round work scale
    with cohort size, not population size (``BENCH_population.json``
    pins rounds/sec flat from 10³ to 10⁶ devices).

Resampling policies (``PopulationSpec.resample``):
  * ``"round"``  — a fresh cohort every global round (the cross-device FL
    default; within a round the cohort is fixed across the K edge rounds);
  * ``"static"`` — one cohort drawn at round 0 and kept for the whole run;
  * ``"full"``   — the identity cohort (requires ``population == N × J``):
    every device participates every round.  This is the bridge to the
    fixed-membership simulator — and the parity lever:
    ``store.subset(ids)`` materializes the sampled rows as a small
    ``"full"``-mode population whose run is bitwise-identical to the
    gathered cohort's (tests/test_population.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import partition

_RESAMPLE = ("round", "static", "full")


@dataclasses.dataclass(frozen=True)
class PopulationSpec:
    """Shape and profile distribution of a device population."""

    size: int                  # P — number of devices that exist
    j_cohort: int              # devices gathered per edge per round
    resample: str = "round"    # "round" | "static" | "full"
    miss_frac: float = 0.2     # population-mean straggle probability
    miss_conc: float = 8.0     # Beta concentration (higher = homogeneous)
    speed_sigma: float = 0.25  # lognormal sigma of time_scale (mean 1)

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"population size must be >= 1, got {self.size}")
        if self.j_cohort < 1:
            raise ValueError(f"j_cohort must be >= 1, got {self.j_cohort}")
        if self.resample not in _RESAMPLE:
            raise ValueError(f"resample must be one of {_RESAMPLE}, "
                             f"got {self.resample!r}")
        if not 0.0 <= self.miss_frac <= 1.0:
            raise ValueError("miss_frac must be in [0, 1]")


class DevicePopulation:
    """Seed-major store of ``[population]`` device profiles.

    Profiles are synthesized from three independent sub-streams of the
    given seed (class assignment, miss propensity, speed), so growing the
    population or adding a profile field never re-keys the others.
    """

    def __init__(self, spec: PopulationSpec, *, n_classes: int,
                 max_classes: int = 1, seed: int = 0):
        self.spec = spec
        self.seed = int(seed)
        kids = np.random.SeedSequence(self.seed).spawn(3)
        P = spec.size
        self.classes = partition.population_classes(
            P, n_classes, max_classes, seed=kids[0])      # [P, M] i32
        if spec.miss_frac <= 0.0:
            self.miss_prob = np.zeros(P)
        elif spec.miss_frac >= 1.0:
            self.miss_prob = np.ones(P)
        else:
            a = spec.miss_conc * spec.miss_frac
            b = spec.miss_conc * (1.0 - spec.miss_frac)
            self.miss_prob = np.random.default_rng(kids[1]).beta(a, b, P)
        sig = spec.speed_sigma
        self.time_scale = np.random.default_rng(kids[2]).lognormal(
            mean=-0.5 * sig * sig, sigma=sig, size=P) if sig > 0 \
            else np.ones(P)                           # E[time_scale] = 1

    @property
    def size(self) -> int:
        return self.spec.size

    def cohort_ids(self, t_rounds: int, n_edges: int, seed: int
                   ) -> np.ndarray:
        """Occupant ids ``[T, N, J_cohort]`` for every global round.

        Sampling is with replacement and O(T × N × J) regardless of the
        population size.  ``seed`` should be the deployment's ``"cohort"``
        stream (``core.rng.stream_seed``).
        """
        N, J = n_edges, self.spec.j_cohort
        if self.spec.resample == "full":
            if self.size != N * J:
                raise ValueError(
                    f"resample='full' requires population == N*J_cohort "
                    f"({N}*{J}={N * J}), got {self.size}")
            ids = np.arange(self.size, dtype=np.int64).reshape(N, J)
            return np.broadcast_to(ids, (t_rounds, N, J)).copy()
        rng = np.random.default_rng(np.random.SeedSequence(int(seed)))
        if self.spec.resample == "static":
            ids = rng.integers(0, self.size, size=(N, J))
            return np.broadcast_to(ids, (t_rounds, N, J)).copy()
        return rng.integers(0, self.size, size=(t_rounds, N, J))

    def subset(self, ids: np.ndarray) -> "DevicePopulation":
        """Materialize the profile rows ``ids`` as a ``"full"``-mode
        population of ``len(ids) == N*J`` devices (parity/testing lever:
        a gathered cohort and its materialized subset run identically)."""
        ids = np.asarray(ids, dtype=np.int64).ravel()
        sub = object.__new__(DevicePopulation)
        sub.spec = dataclasses.replace(self.spec, size=len(ids),
                                       resample="full")
        sub.seed = self.seed
        sub.classes = self.classes[ids].copy()
        sub.miss_prob = self.miss_prob[ids].copy()
        sub.time_scale = self.time_scale[ids].copy()
        return sub


def as_population(population, j_cohort, *, n_classes: int, max_classes: int,
                  seed: int) -> DevicePopulation:
    """Coerce the simulator's ``population=`` argument into a store.

    Accepts a ready ``DevicePopulation`` (shared across sweep points — the
    store is profile data, the O(P) part, so build it once), a
    ``PopulationSpec``, or a plain int population size (then ``j_cohort``
    must be given).  ``seed`` should be the deployment's ``"population"``
    stream and is only used when the store is built here.
    """
    if isinstance(population, DevicePopulation):
        if j_cohort is not None and j_cohort != population.spec.j_cohort:
            raise ValueError(
                f"j_cohort={j_cohort} conflicts with the population store's "
                f"j_cohort={population.spec.j_cohort}")
        return population
    if isinstance(population, PopulationSpec):
        spec = population
        if j_cohort is not None and j_cohort != spec.j_cohort:
            raise ValueError(f"j_cohort={j_cohort} conflicts with "
                             f"spec.j_cohort={spec.j_cohort}")
    else:
        if j_cohort is None:
            raise ValueError("population given as an int needs an explicit "
                             "j_cohort (devices per edge per round)")
        spec = PopulationSpec(size=int(population), j_cohort=int(j_cohort))
    return DevicePopulation(spec, n_classes=n_classes,
                            max_classes=max_classes, seed=seed)
