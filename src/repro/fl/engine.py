"""Fully-jitted batched BHFL simulation engine.

The legacy ``BHFLSimulator.run`` loop dispatches one jitted aggregation call
per edge per edge-round plus host-side numpy batching — every sweep point
pays per-edge dispatch and host→device transfer overhead.  This engine
compiles an ENTIRE run into one program:

  * ragged ``j_per_edge`` is padded into a dense ``[N, J_max]`` device layout
    with a boolean ``valid`` mask (padded slots carry zero aggregation
    weight and are overwritten by the edge sync every round),
  * HieAvg edge aggregation is one vmapped ``_mix_and_update`` across all N
    edges instead of N sequential calls,
  * straggler masks, batch indices, and the learning-rate schedule are
    precomputed host-side into dense arrays (``core.straggler.stack_ragged``),
  * the K edge rounds and the global aggregation are driven by nested
    ``jax.lax.scan`` — one global round is one fused XLA computation, and the
    T rounds run without returning to Python,
  * the program is *shape-polymorphic via padding*: ``build_inputs`` can pad
    every array dim (T/K/N/J/steps) past a deployment's own extents, and
    ``run_engine`` treats everything padded as a numeric no-op — this is
    what lets the sweep planner (``repro.fl.sweep``) batch grid points that
    disagree on topology or round counts into a handful of compiled,
    mesh-sharded calls (shape buckets),
  * the data plane is *seed-major*: train/test/init arrays carry a leading
    ``[n_seeds]`` axis and every run gathers its own dataset by the scalar
    ``seed_idx`` — under the sweep fabric the data plane is shared across
    all grid points (vmap ``in_axes=None`` / ``shard_map`` replicated), so
    a multi-seed confidence grid holds the *distinct-seed* count in device
    memory, not one dataset copy per point,
  * the hot path (warm HieAvg aggregation at both hierarchy layers, the
    train-step SGD update) routes through the *kernel plane*
    (``repro.kernels.dispatch``): a static ``kernel_mode`` knob selects
    the fused Pallas kernels on TPU/GPU, the pure-XLA reference on CPU
    ("auto"), or the Pallas interpreter for validation — and the
    donating entries (``run_engine_donated``; ``split_inputs`` /
    ``SHARED_DATA_FIELDS``) hand the per-run input planes to the
    compiled call so callers stop holding a second copy.

The padding/validity-mask contract and the seed-dedup invariants are
documented in docs/ARCHITECTURE.md (§Engine); tests/test_sweep_fabric.py
enforces both.

The Raft chain (control plane, no model numerics) is replayed host-side
*before* the jitted run: it consumes the same RNG stream in the same order as
the legacy loop, so leader failover produces identical edge masks.

Parity with ``BHFLSimulator.run_legacy`` is tested in
``tests/test_engine_parity.py``; throughput is tracked in
``BENCH_engine.json`` (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, hieavg
from repro.core import latency as lat
from repro.core import rng as rng_streams
from repro.core import straggler as strag
from repro.data import partition
from repro.fl import faults as _faults
from repro.kernels import dispatch as kernel_dispatch
from repro.models import (cnn_accuracy_fast, cnn_loss, cnn_loss_fast,
                          init_from_specs)
from repro.optim import paper_lr

PyTree = Any


# --------------------------------------------------------------- local step
def train_epoch_body(params: PyTree, images: jnp.ndarray,
                     labels: jnp.ndarray, lr: jnp.ndarray,
                     loss_fn=None,
                     step_ok: Optional[jnp.ndarray] = None,
                     kernel_mode: str = "xla") -> tuple[PyTree, jnp.ndarray]:
    """One local epoch for all devices.  params: stacked [D, ...];
    images: [D, steps, B, H, W, 1]; labels: [D, steps, B]. Returns
    (new stacked params, mean loss per device [D]).

    scan(vmap(step)) rather than vmap(scan): one fused all-device matmul per
    step instead of D separate small ones.  The engine trains with the
    im2col conv (``cnn_loss_fast``); the legacy reference loop keeps the
    shifted-sum conv (same math, different summation order).

    ``step_ok`` (optional, [steps] f32 of 0/1): per-step validity for the
    sweep fabric, whose grid points may disagree on steps-per-epoch.  A
    padded step (0) applies no update and is excluded from the mean loss;
    a real step multiplies lr by 1.0, which is exact in f32, so a fully
    valid mask is bitwise identical to passing ``None``.

    ``kernel_mode`` (resolved — ``"pallas"``/``"interpret"``/``"xla"``):
    routes the inner SGD update through ``kernels.dispatch.sgd_update`` —
    the fused one-pass kernel on accelerators, the original ``tree.map``
    on the XLA path — and, when ``loss_fn`` is None (the default), the
    conv blocks inside the loss through the fused conv kernel
    (``cnn_loss_fast(kernel_mode=...)``).  An explicit ``loss_fn``
    (``run_legacy``'s shifted-sum ``cnn_loss``) is used as-is.  The
    padded-step mask folds into the kernel's scale (0 → exact identity)
    so padding stays a numeric no-op on every path.
    """
    if loss_fn is None:
        loss_fn = partial(cnn_loss_fast, kernel_mode=kernel_mode)

    def step(ps, xs):
        if step_ok is None:
            im, lb = xs                                 # [D, B, ...]
            scale = lr
        else:
            im, lb, ok = xs
            scale = lr * ok
        loss, g = jax.vmap(jax.value_and_grad(loss_fn))(ps, im, lb)
        ps = kernel_dispatch.sgd_update(ps, g, scale, mode=kernel_mode)
        return ps, loss

    images = jnp.swapaxes(images, 0, 1)                 # [steps, D, ...]
    labels = jnp.swapaxes(labels, 0, 1)
    if step_ok is None:
        params, losses = jax.lax.scan(step, params, (images, labels))
        return params, jnp.mean(losses, axis=0)
    params, losses = jax.lax.scan(step, params, (images, labels, step_ok))
    n_ok = jnp.maximum(jnp.sum(step_ok), 1.0)
    return params, jnp.sum(losses * step_ok[:, None], axis=0) / n_ok


# jitted legacy-exact epoch (shifted-sum conv), used by run_legacy
train_epoch = jax.jit(partial(train_epoch_body, loss_fn=cnn_loss))


# ------------------------------------------------------------ dense inputs
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineInputs:
    """Everything a jitted run consumes, as dense device arrays.

    Leaves are stackable across grid points (the sweep fabric vmaps or
    shard_maps over a leading point axis); gamma0/lam/t_cold_boot ride along
    as scalars so decay-factor sweeps are data, not recompiles.

    The array dims T/K/N/J/steps are *bucket maxima* when the inputs were
    built with pad targets (``build_inputs(..., t_max=...)``): the
    ``t_valid``/``k_valid``/``n_valid``/``s_valid`` scalars carry each
    point's real extents, and ``run_engine`` turns everything padded into a
    numeric no-op — padded device/edge slots get zero aggregation weight
    (``valid``/``j_arr``), padded edge rounds and global rounds carry the
    scan state through unchanged, padded SGD steps apply no update.

    Data-plane fields (train/test/init, ``engine.SHARED_DATA_FIELDS``) are
    *seed-major*: a leading ``[S]`` axis of distinct seeds, gathered per
    run by the scalar ``seed_idx``.  The sweep fabric never stacks them
    along the point axis — they are shared (replicated) across the whole
    grid, so device-resident data scales with the distinct-seed count.
    A standalone ``build_inputs`` emits ``S=1`` with ``seed_idx=0``.
    """

    train_x: jnp.ndarray      # [S, n_train, H, W, 1] f32 (seed-major)
    train_y: jnp.ndarray      # [S, n_train] i32
    test_x: jnp.ndarray       # [S, n_test, H, W, 1] f32
    test_y: jnp.ndarray       # [S, n_test] i32
    init_w: PyTree            # [S, ...] global model at t=0, per seed
    seed_idx: jnp.ndarray     # scalar i32 — this run's row of the [S] axis
    batch_idx: jnp.ndarray    # [T, K, N, J, steps, B] i32 into train_x
    has_data: jnp.ndarray     # [N, J] f32 — 0 for empty-shard/padded slots
    valid: jnp.ndarray        # [N, J] bool — real device slots
    dev_masks: jnp.ndarray    # [T, K, N, J] bool submission masks
    edge_masks: jnp.ndarray   # [T, N] bool (failover already applied)
    lr: jnp.ndarray           # [T, K] f32 paper schedule (0 when padded)
    j_arr: jnp.ndarray        # [N] f32 devices per edge (0 = padded edge)
    gamma0: jnp.ndarray       # scalar f32
    lam: jnp.ndarray          # scalar f32
    t_cold_boot: jnp.ndarray  # scalar i32
    t_valid: jnp.ndarray      # scalar i32 — real global rounds (<= T)
    k_valid: jnp.ndarray      # scalar i32 — real edge rounds (<= K)
    n_valid: jnp.ndarray      # scalar i32 — real edges (<= N).  Metadata
    #   for callers/tests: run_engine itself never reads it — padded edges
    #   are inert purely through their all-False ``valid`` rows and zero
    #   ``j_arr`` weights.
    s_valid: jnp.ndarray      # scalar i32 — real SGD steps/epoch (<= steps)
    # --- latency plane (PR 3): precomputed per-round time draws feeding
    # the engine's simulated clock.  Padded slots/rounds are zero.
    dev_time: jnp.ndarray     # [T, K, N, J] f32 — per-device round time
    #   (2*LM + LP draws, straggler submissions delayed + deadline-capped;
    #   population mode folds the occupant's speed profile in)
    cons_time: jnp.ndarray    # [T] f32 — per-round consensus latency L_bc
    #   (replayed consensus-chain election + commit — the zoo protocol the
    #   setting names — scaled by consensus_mult)
    cons_energy: jnp.ndarray  # [T] f32 — per-round consensus energy (J),
    #   the chain's ``.energy`` differenced per round.  Zero on padded
    #   rounds (the energy axis's padding inertness is bitwise); never
    #   scaled by consensus_mult.
    edge_hop: jnp.ndarray     # scalar f32 — 2 * E[LM'] edge<->leader hop
    # --- population/cohort plane (PR 6): the engine's per-round arrays are
    # already COHORT-sized ([N, J] = the gathered cohort, not the
    # population) — the only trace the population leaves here is churn:
    cohort_change: jnp.ndarray  # [T, N, J] bool — slot occupant changed at
    #   the start of global round t (all-False for fixed membership).
    #   Resets the delayed-gradient pending/age state of the slot; HieAvg
    #   histories are slot-stream-keyed under churn (documented in
    #   docs/ARCHITECTURE.md).
    # --- aggregation-mode plane (PR 6): traced per-point scalars so an
    # aggregation-strategy axis is sweep DATA, not a recompile.  Only the
    # static aggregator="switched" engine reads agg_sel; stale_beta/
    # delay_delta feed delayed_grad (direct or switched).
    agg_sel: jnp.ndarray      # scalar i32 — 0 hieavg, 1 delayed_grad,
    #   2 fedavg (see AGG_SEL)
    stale_beta: jnp.ndarray   # scalar f32 — delayed-grad staleness
    #   discount beta (setting.staleness_discount)
    delay_delta: jnp.ndarray  # scalar f32 — max tolerated consecutive-miss
    #   staleness delta (setting.delay_delta)


#: ``EngineInputs`` fields that form the seed-major data plane: a pure
#: function of (seed, grid-constant geometry), carried with a leading
#: ``[S]`` distinct-seed axis and shared — never stacked per point — by
#: the sweep fabric (vmap ``in_axes=None`` / shard_map replicated), and
#: never *donated*: every bucket of a plan (and every same-seed point via
#: ``share_data_from``) aliases the same device buffers, so handing them
#: to XLA for reuse would invalidate the other aliases.
SHARED_DATA_FIELDS = frozenset({"train_x", "train_y", "test_x", "test_y",
                                "init_w"})

#: ``agg_sel`` encoding for the ``"switched"`` engine — the aggregation
#: strategies that can share one compiled program as a traced axis.
AGG_SEL = {"hieavg": 0, "delayed_grad": 1, "fedavg": 2}


def split_inputs(inp: EngineInputs, *, shared_seed_idx: bool = False
                 ) -> tuple[dict, dict]:
    """Split an ``EngineInputs`` into ``(hot, shared)`` field dicts.

    ``hot`` holds the per-run (sweep: per-point stacked) planes — safe to
    donate to the compiled run, so a big bucketed grid does not hold two
    copies of the stacked state (caller buffers + device working set)
    while it executes.  ``shared`` holds the seed-major data plane, which
    is aliased across buckets/points and therefore never donated (and
    never mapped/sharded — see ``launch.sharding.sweep_data_spec``).

    ``shared_seed_idx``: on single-seed sweep plans ``seed_idx`` is a
    plan-wide scalar 0 and rides the shared side (keeping the engine's
    test/init gathers unbatched under vmap); multi-seed plans stack it
    per point, so it belongs to the hot side like every stacked field.
    """
    hot, shared = {}, {}
    for f in dataclasses.fields(EngineInputs):
        side = shared if (f.name in SHARED_DATA_FIELDS
                          or (f.name == "seed_idx" and shared_seed_idx)) \
            else hot
        side[f.name] = getattr(inp, f.name)
    return hot, shared


def merge_inputs(hot: dict, shared: dict) -> EngineInputs:
    """Inverse of ``split_inputs`` (used inside the jitted runners)."""
    return EngineInputs(**hot, **shared)


def replay_chain(sim) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Replay the control plane exactly as the legacy loop interleaves it:
    elect → (maybe crash the leader) → commit, once per global round —
    now under the deployment's fault schedule (``repro.fl.faults``).

    Per round, the schedule's churn planes are diff-applied onto the
    chain's alive set (``fail_node``/``recover_node``) before the protocol
    round runs, so alive counts — and with them latency and energy — vary
    over rounds; a below-quorum round runs the schedule's bounded
    stall-and-retry policy (``faults.stalled_round``), with the backoff
    landing in that round's consensus-latency draw (the engine's C2 stall
    accounting picks it up).  Mutates only ``sim.chain`` (plus the
    ``sim._failed_leader`` crash memo); ``sim.edge_masks`` is never
    touched — the failover/outage mask is *derived* per replay, so a
    repeated ``run()`` is bitwise repeatable under a leader crash.  The
    chain RNG stream is consumed in the same order as the legacy loop
    (an inert schedule adds zero draws), so the same leaders win.  The
    ``fail_leader_at`` crash is applied at most once per simulator: a
    repeated ``run()`` replays the same failed edge instead of killing
    another leader (which would eventually lose quorum).

    Returns ``(cons [T], energy [T], edge_avail [T, N])``: per-round
    consensus latency (election + commit + any stall backoff, simulated
    seconds) and consensus energy (the chain's cumulative ``.energy``
    differenced per round, Joules) — the discrete-event draws the engine's
    clock and energy accounting consume — plus the derived per-round edge
    availability (crashed leader from its crash round on, scheduled edge
    outages, lost global submissions) that ``build_inputs`` ANDs into the
    ``edge_masks`` plane.
    """
    sched = sim.fault_schedule
    crash_at = sched.spec.leader_crash_round
    failed_edge: Optional[int] = getattr(sim, "_failed_leader", None)
    T = sim.s.t_global_rounds
    cons = np.zeros(T, np.float64)
    energy = np.zeros(T, np.float64)
    pinned = set() if failed_edge is None else {failed_edge}
    for t in range(1, T + 1):
        crash = crash_at is not None and t == crash_at and failed_edge is None
        elapsed, de, _, crashed = _faults.stalled_round(
            sim.chain, t, sched, pinned_down=pinned, crash_leader=crash)
        if crashed is not None:
            failed_edge = crashed
            sim._failed_leader = crashed
            pinned.add(crashed)
        cons[t - 1] = elapsed
        energy[t - 1] = de
    edge_avail = ~sched.edge_down & ~sched.edge_msg_drop    # [T, N]
    if failed_edge is not None:
        # from the crash round on — same extent the old in-place mutation
        # produced, but derived fresh per replay
        edge_avail[crash_at - 1:, failed_edge] = False
    return cons, energy, edge_avail


def build_inputs(sim, *, t_max: Optional[int] = None,
                 k_max: Optional[int] = None, n_max: Optional[int] = None,
                 j_max: Optional[int] = None,
                 steps_max: Optional[int] = None,
                 share_data_from: Optional[EngineInputs] = None
                 ) -> EngineInputs:
    """Precompute a ``BHFLSimulator``'s whole run into dense device arrays.

    Batch indices are sampled from a fresh ``default_rng(seed)`` in the same
    (round, device) order as the legacy loop's per-round ``_epoch_batches``,
    so a fresh legacy instance and a fresh engine instance see identical
    batches.  Also replays the Raft chain (see ``replay_chain``).

    The ``*_max`` targets pad the emitted arrays past this deployment's own
    extents — how the sweep planner (``repro.fl.sweep``) stacks grid points
    that disagree on topology or round counts.  Padding is all-inert:
    padded rounds get zero lr and all-False masks, padded edges get
    ``j_arr`` 0 and all-False ``valid`` rows, padded steps index sample 0
    but are masked out of the SGD update.  The real extents ride along in
    ``t_valid``/``k_valid``/``n_valid``/``s_valid``.

    ``share_data_from``: reuse another point's train/test/init device
    buffers instead of converting this sim's own — the sweep planner's
    same-seed dedup (the caller guarantees the seed and data geometry
    match, which makes those arrays byte-identical; see
    ``engine.SHARED_DATA_FIELDS``).  The emitted data plane always carries
    the seed-major ``[S=1]`` leading axis with ``seed_idx=0``; the planner
    concatenates distinct-seed planes and rewrites ``seed_idx`` per point
    when it stacks a grid.
    """
    s = sim.s
    T, K, N = s.t_global_rounds, s.k_edge_rounds, sim.N
    steps, bs = sim.steps, s.batch_size
    Tm, Km, Nm = t_max or T, k_max or K, n_max or N
    Sm = steps_max or steps
    if (Tm < T or Km < K or Nm < N or Sm < steps
            or (j_max is not None and j_max < max(sim.j_per_edge))):
        raise ValueError("pad targets must be >= the deployment's extents")

    cons_draws, energy_draws, edge_avail = replay_chain(sim)

    dense_dev, valid = strag.stack_ragged(sim.dev_masks, j_max=j_max,
                                          n_max=Nm)
    J = valid.shape[1]
    # ---- fault plane (repro.fl.faults): a down edge trains nothing (all
    # its device submissions cleared for the round's K edge rounds — the
    # edge-layer HieAvg miss_counts span the outage exactly like the
    # global layer's), and a burst/lost-message device misses its edge
    # round.  Both fold into the submission masks BEFORE the latency
    # computation, so a dropped submission is deadline-capped exactly
    # like a straggler miss.  The inert schedule skips the folding (and
    # the copy) entirely — bitwise parity with the pre-chaos path.
    sched = sim.fault_schedule
    if sched.edge_down.any() or sched.dev_drop.any():
        dense_dev = dense_dev.copy()
        if sched.edge_down.any():
            ed = np.repeat(sched.edge_down, K, axis=0)       # [T*K, N]
            dense_dev[:T * K, :N] &= ~ed[:, :, None]
        if sched.dev_drop.any():
            dd = sched.dev_drop                              # [T*K, N, Js]
            dense_dev[:T * K, :N, :dd.shape[2]] &= ~dd
    dev_masks = np.zeros((Tm, Km, Nm, J), dtype=bool)
    dev_masks[:T, :K] = dense_dev[:T * K].reshape(T, K, Nm, J)
    edge_masks = np.zeros((Tm, Nm), dtype=bool)
    edge_masks[:T, :N] = np.asarray(sim.edge_masks[:T], dtype=bool) \
        & edge_avail

    # batch indices in legacy order: per edge-round, per device.  The
    # fresh generator rides the deployment's "batches" SeedSequence stream
    # (core.rng) — the same stream run_legacy opens per run, so a legacy
    # and an engine run of one instance see identical batches.
    rng = rng_streams.stream_rng(sim.seed, "batches")
    R = T * K
    if getattr(sim, "pop", None) is not None:
        # population mode: one vectorized draw for all (round, slot)
        # pairs — the occupant's classes select the sample pools, the
        # draws are slot-keyed.  O(R x cohort), never O(population).
        ids_r = np.repeat(sim.cohort_ids, K, axis=0).reshape(R, sim.D)
        cls_rd = sim.pop.classes[ids_r.reshape(-1)]      # [R*D, M]
        flat_idx = partition.sample_class_batches(
            sim._pool, sim._pool_off, sim._pool_cnt, cls_rd, steps, bs,
            rng).reshape(R, sim.D, steps, bs)
        flat_has = np.ones((sim.D,), np.float32)
    else:
        flat_idx = np.zeros((R, sim.D, steps, bs), np.int32)
        flat_has = np.zeros((sim.D,), np.float32)
        for r in range(R):
            for d, idx in enumerate(sim.device_idx):
                if len(idx) == 0:
                    continue
                flat_idx[r, d] = rng.choice(idx, size=(steps, bs),
                                            replace=True)
                flat_has[d] = 1.0
    # per-device round-time draws (latency fabric).  A separate RNG stream
    # from the batch sampler above: adding latency accounting must not
    # perturb batch draws (legacy parity).  Draws cover only the REAL
    # (T, K, D) extents so a point padded to larger grid maxima sees
    # byte-identical times (padding stays a numeric no-op).  Population
    # mode scales each slot's draw by the round occupant's speed profile.
    lp = sim.lat
    lrng = rng_streams.stream_rng(sim.seed, "latency")
    jm = lrng.uniform(1.0 - lp.lm_jitter, 1.0 + lp.lm_jitter, (R, sim.D))
    jp = lrng.uniform(1.0 - lp.lp_jitter, 1.0 + lp.lp_jitter, (R, sim.D))
    draw = 2.0 * lp.lm_device * jm + lp.lp_device * jp
    spd = sim.cohort_time_scale() if getattr(sim, "pop", None) is not None \
        else None
    if spd is not None:
        draw = draw * spd
    elif lp.rate_mult is not None:
        # heterogeneous fleet: device d's clock rate scales every one of
        # its round draws (before straggler slowdown / deadline capping,
        # exactly like a population occupant's time_scale would)
        rm = np.asarray(lp.rate_mult, np.float64).reshape(-1)
        if rm.shape != (sim.D,):
            raise ValueError(
                f"LatencyParams.rate_mult must have one entry per device "
                f"({sim.D}), got shape {rm.shape}")
        draw = draw * rm[None, :]
    draw = draw.reshape(T, K, sim.D)
    deadline = lat.device_deadline(lp)
    sub = dense_dev[:R].reshape(T, K, Nm, J)    # real submission masks

    batch_idx = np.zeros((Tm, Km, Nm, J, Sm, bs), np.int32)
    has_data = np.zeros((Nm, J), np.float32)
    dev_time = np.zeros((Tm, Km, Nm, J), np.float32)
    rect = flat_idx.reshape(T, K, sim.D, steps, bs)
    d = 0
    for e in range(N):
        for j in range(sim.j_per_edge[e]):
            batch_idx[:T, :K, e, j, :steps] = rect[:, :, d]
            has_data[e, j] = flat_has[d]
            # a straggler's submission is delayed (slowdown x draw); the
            # edge proceeds at the deadline without it — deadline-based
            # aggregation, so its round time is capped there
            dly = np.where(sub[:, :, e, j], draw[:, :, d],
                           draw[:, :, d] * lp.straggler_slowdown)
            dev_time[:T, :K, e, j] = np.minimum(dly, deadline)
            d += 1
    cons_time = np.zeros((Tm,), np.float32)
    cons_time[:T] = cons_draws * float(s.consensus_mult)
    # energy is a protocol cost, not a latency knob: consensus_mult never
    # scales it.  Padded rounds stay exactly 0.0 (bitwise-inert additions).
    cons_energy = np.zeros((Tm,), np.float32)
    cons_energy[:T] = energy_draws

    lr = np.zeros((Tm, Km), np.float32)
    lr[:T, :K] = np.asarray(
        paper_lr(jnp.arange(R), s.lr0, s.lr_decay)).reshape(T, K)
    j_arr = np.zeros((Nm,), np.float32)
    j_arr[:N] = sim.j_per_edge

    # cohort churn (population mode: occupant changed at round start;
    # all-False for fixed membership) — padded rounds/edges stay False
    cohort_change = np.zeros((Tm, Nm, J), dtype=bool)
    if hasattr(sim, "cohort_change"):
        chg = sim.cohort_change()
        cohort_change[:T, :N, :chg.shape[2]] = chg

    if share_data_from is not None:
        src = share_data_from
        train_x, train_y = src.train_x, src.train_y
        test_x, test_y, init_w = src.test_x, src.test_y, src.init_w
    else:
        # [None]: the seed-major [S=1] axis (a reshape of the device
        # buffer, not a copy)
        train_x = jnp.asarray(sim.train_x)[None]
        train_y = jnp.asarray(sim.train_y)[None]
        test_x = jnp.asarray(sim.test_x)[None]
        test_y = jnp.asarray(sim.test_y)[None]
        init_w = jax.tree.map(
            lambda x: x[None],
            init_from_specs(sim.specs, jax.random.key(sim.seed)))

    return EngineInputs(
        train_x=train_x, train_y=train_y,
        test_x=test_x, test_y=test_y, init_w=init_w,
        seed_idx=jnp.int32(0),
        batch_idx=jnp.asarray(batch_idx),
        has_data=jnp.asarray(has_data), valid=jnp.asarray(valid),
        dev_masks=jnp.asarray(dev_masks), edge_masks=jnp.asarray(edge_masks),
        lr=jnp.asarray(lr), j_arr=jnp.asarray(j_arr),
        gamma0=jnp.float32(s.gamma0), lam=jnp.float32(s.lam),
        t_cold_boot=jnp.int32(s.t_cold_boot),
        t_valid=jnp.int32(T), k_valid=jnp.int32(K),
        n_valid=jnp.int32(N), s_valid=jnp.int32(steps),
        dev_time=jnp.asarray(dev_time), cons_time=jnp.asarray(cons_time),
        cons_energy=jnp.asarray(cons_energy),
        edge_hop=jnp.float32(2.0 * lp.lm_edge),
        cohort_change=jnp.asarray(cohort_change),
        agg_sel=jnp.int32(AGG_SEL.get(sim.aggregator, 0)),
        stale_beta=jnp.float32(s.staleness_discount),
        delay_delta=jnp.float32(s.delay_delta))


# ------------------------------------------------------------- jitted run
def _bcast_edges_tree(tree: PyTree, n: int) -> PyTree:
    """Broadcast a global model to per-edge copies: [...] -> [N, ...]."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)


def _bcast_devices_tree(tree: PyTree, n: int, j: int) -> PyTree:
    """Broadcast edge models to device slots: [N, ...] -> [N, J, ...]."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[:, None], (n, j) + x.shape[1:]), tree)


def init_engine_carry(inp: EngineInputs, history_dtype=None) -> tuple:
    """The engine scan's round-zero carry (the full cross-round state:
    device/edge/global models, both HieAvg histories, the d_fedavg /
    delayed-grad stores and ages, the simulated clock, and the cumulative
    consensus energy).

    Extracted from ``_engine_body`` so chunked execution
    (``run_engine_chunk`` / ``BHFLSimulator.run_checkpointed``) can build
    the same round-zero state outside the jit, checkpoint a mid-run carry,
    and feed it back — the carry IS the whole resume state.  Values are
    identical to the inline construction (broadcasts and zeros are exact).
    """
    N, J = inp.dev_masks.shape[2:]
    init_w = jax.tree.map(lambda v: v[inp.seed_idx], inp.init_w)
    edge0 = _bcast_edges_tree(init_w, N)
    dev0 = _bcast_devices_tree(edge0, N, J)
    return (dev0,
            hieavg.init_history_batched(dev0, history_dtype),  # @r==0
            jax.tree.map(jnp.zeros_like, dev0),      # d_fedavg last /
            #   delayed_grad pending stores (mutually exclusive users)
            hieavg.init_history(edge0, history_dtype),         # @t==1
            jax.tree.map(jnp.zeros_like, edge0),
            init_w,
            jnp.float32(0.0),                        # simulated clock
            jnp.zeros((N, J), jnp.float32),   # delayed-grad edge ages
            jnp.zeros((N,), jnp.float32),     # delayed-grad global ages
            jnp.float32(0.0))                 # cumulative consensus J


#: ``EngineInputs`` fields with a leading global-round (T) axis — what
#: ``slice_rounds`` cuts per chunk for resumable execution.
ROUND_FIELDS = ("batch_idx", "dev_masks", "edge_masks", "lr", "dev_time",
                "cons_time", "cons_energy", "cohort_change")


def slice_rounds(inp: EngineInputs, t0: int, t1: int) -> EngineInputs:
    """A view of ``inp`` restricted to global rounds ``t0..t1-1`` (0-based
    rows of the T-leading planes).  Scalars — including the GLOBAL
    ``t_valid`` — ride along unchanged: the engine's round conditions
    (cold boot, history init, validity) compare against absolute round
    numbers, which is what makes chunked execution bitwise-composable."""
    return dataclasses.replace(
        inp, **{f: getattr(inp, f)[t0:t1] for f in ROUND_FIELDS})


def _engine_body(inp: EngineInputs, *, aggregator: str = "hieavg",
                 normalize: bool = False, history_dtype=None,
                 kernel_mode: str = "auto",
                 carry0: Optional[tuple] = None,
                 t_start: Optional[jnp.ndarray] = None,
                 with_carry: bool = False
                 ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                            jnp.ndarray, jnp.ndarray]:
    """One whole BHFL run as a single compiled program.

    Returns per-global-round (accuracy [T], mean local loss [T],
    global-model round-to-round delta norm [T], simulated clock [T],
    cumulative consensus energy [T] in Joules).

    The energy row is the second traced cost axis beside the clock: the
    per-round ``cons_energy`` draws (the replayed chain's ``.energy``
    differenced per round — see ``replay_chain``) accumulate through the
    scan carry exactly like the clock.  Padded rounds contribute a
    bitwise-exact zero (the draw is 0.0 AND the carry passes through);
    rounds past ``t_valid`` repeat the final cumulative value.

    The clock is the latency fabric's cumulative simulated seconds after
    each global round: per edge round the slowest valid device's time draw
    (stragglers delayed, deadline-capped — see ``build_inputs``), summed
    over the K valid edge rounds per edge, maxed over the edges the global
    aggregation waits for (submitting edges; all valid edges when none
    submitted), plus the edge<->leader hop and any consensus stall
    ``max(0, L_bc - edge window)`` — constraint C2 made empirical: when
    consensus hides inside the K-round window it costs nothing, otherwise
    the round waits out the difference.  Rounds past ``t_valid`` repeat
    the final valid clock (like accuracy).

    Dims past the point's ``t_valid``/``k_valid``/``s_valid`` extents are
    sweep-fabric padding: a padded edge round or global round computes and
    then *discards* its result (the scan carry passes through unchanged,
    which under vmap costs the same as a branch anyway), a padded SGD step
    applies no update, and padded edge/device slots carry zero aggregation
    weight via ``valid``/``j_arr``.  Output rounds past ``t_valid`` repeat
    the final valid global model (accuracy) and report 0 loss/delta.

    Training data, the test split, and the init weights are gathered from
    the seed-major ``[S]`` data plane by ``inp.seed_idx``.  The seed index
    is folded straight into the batch gather (``train_x[seed_idx, bidx]``)
    so no per-point copy of the *training set* — the dominant input — is
    ever materialized; the test/init gathers are whole-row, so the sweep
    fabric keeps ``seed_idx`` unmapped on single-seed plans (the gathers
    then stay unbatched: one shared test split under vmap) and only
    multi-seed plans pay a per-point ``[P, n_test, ...]`` eval gather.

    ``history_dtype`` overrides HieAvg's history storage dtype end-to-end
    (EXPERIMENTS.md X1): bf16 cuts the two-model-copies-per-layer memory
    cost 2× for free, f8 4× at an accuracy cost; estimation math stays f32.

    ``aggregator`` is static: ``"hieavg"``/``"t_fedavg"``/``"d_fedavg"``/
    ``"delayed_grad"``/``"fedavg"`` trace only their own branch;
    ``"switched"`` traces hieavg, delayed_grad, AND fedavg and picks per
    run by the *traced* ``inp.agg_sel`` scalar — the sweep fabric's
    mixed-aggregation grids batch into one compiled program that way
    (the unselected strategies are the batching cost).  Delayed-gradient
    state (pending stores + consecutive-miss ages, both layers) rides the
    scan carry; ``inp.cohort_change`` resets a slot's pending/age when
    population-mode churn hands the slot to a new occupant.

    ``kernel_mode`` routes every heavy round phase through the kernel
    plane (``repro.kernels.dispatch.ROUND_PHASES``): the conv forward/
    backward inside the train step, the SGD update, the warm HieAvg
    edge/global aggregations, the cold-boot means, the FedAvg and
    delayed-gradient aggregates (the "switched" set), and the post-scan
    eval head.  ``"auto"`` resolves to the fused Pallas kernels on
    TPU/GPU and the pure-XLA reference on CPU (zero overhead);
    ``"interpret"`` forces the Pallas interpreter (the CPU validation
    path the parity tests pin); ``"xla"`` forces the reference.  Only
    the legacy ``t_fedavg``/``d_fedavg`` baselines and the tiny history
    bookkeeping stay XLA-always (not on the hot path).
    """
    kernel_mode = kernel_dispatch.resolve_kernel_mode(kernel_mode)
    T, K, N, J = inp.dev_masks.shape
    steps, bs = inp.batch_idx.shape[-2:]
    D = N * J
    v32 = inp.valid.astype(jnp.float32)
    hd = inp.has_data
    step_ok = (jnp.arange(steps) < inp.s_valid).astype(jnp.float32)

    def passthru(ok, new, old):
        """Gate a carry update on a traced bool (padding = carry-through)."""
        return jax.tree.map(lambda a, b: jnp.where(ok, a, b), new, old)

    def sel3(sel, a, b, c):
        """Tri-select pytrees by the traced ``agg_sel`` scalar (the
        "switched" engine: 0 = hieavg, 1 = delayed_grad, 2 = fedavg)."""
        return jax.tree.map(
            lambda x, y, z: jnp.where(sel == 0, x, jnp.where(sel == 1, y, z)),
            a, b, c)

    def bleaf(m, x):
        """Broadcast a ``[N, J]`` slot mask against a ``[N, J, ...]`` leaf."""
        return m.reshape(m.shape + (1,) * (x.ndim - 2))

    def bcast_edges(tree):   # [...] global -> [N, ...]
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (N,) + x.shape), tree)

    def bcast_devices(tree):  # [N, ...] edge models -> [N, J, ...]
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[:, None], (N, J) + x.shape[1:]),
            tree)

    def flat(tree):           # [N, J, ...] -> [N*J, ...]
        return jax.tree.map(lambda x: x.reshape((D,) + x.shape[2:]), tree)

    def unflat(tree):
        return jax.tree.map(lambda x: x.reshape((N, J) + x.shape[1:]), tree)

    def global_round(carry, xs):
        prev_carry = carry
        (device_w, ehist, elast, ghist, glast, prev_global, clock,
         eage, gage, energy) = carry
        (t, bidx_t, dmask_t, emask, lr_t, dtime_t, cons_t, cons_en_t,
         chg_t) = xs

        # ---- K edge rounds: local epoch + per-edge aggregation + sync
        def edge_round(c, xs_k):
            prev_c = c
            device_w, ehist, elast, eage = c
            # [N,J,steps,B], [N,J], scalar lr, round counter r, k index,
            # per-device time draws [N,J]
            bidx, dmask, lr, r, k, dtime = xs_k

            x = inp.train_x[inp.seed_idx, bidx] \
                * hd[:, :, None, None, None, None, None]
            y = jnp.where(hd[:, :, None, None] > 0,
                          inp.train_y[inp.seed_idx, bidx], 0)
            pflat, loss = train_epoch_body(
                flat(device_w), x.reshape((D, steps, bs) + x.shape[4:]),
                y.reshape(D, steps, bs), lr, step_ok=step_ok,
                kernel_mode=kernel_mode)
            ws = unflat(pflat)
            dev_loss = loss.reshape(N, J)

            if aggregator in ("hieavg", "switched"):
                ehist = jax.lax.cond(
                    r == 0,
                    lambda h: hieavg.init_history_batched(ws, history_dtype),
                    lambda h: h, ehist)

                def cold(w, m, h):
                    return (kernel_dispatch.edge_aggregate_cold_batched(
                        w, inp.valid, mode=kernel_mode),
                            hieavg.update_history_batched(h, w, m))

                def warm(w, m, h):
                    return kernel_dispatch.edge_aggregate_batched(
                        w, m, h, inp.valid, inp.gamma0, inp.lam, normalize,
                        mode=kernel_mode)

                agg_h, ehist = jax.lax.cond(
                    t <= inp.t_cold_boot, cold, warm, ws, dmask, ehist)
            if aggregator in ("delayed_grad", "switched"):
                # first edge round: everyone counts present (nothing in
                # flight); cohort churn resets the slot's pending/age at
                # the round's first edge round
                m_eff = jnp.logical_or(dmask, r == 0)
                chg = jnp.logical_and(chg_t, k == 0)
                pend = jax.tree.map(
                    lambda p, w: jnp.where(bleaf(chg, w), w, p), elast, ws)
                age = eage * (1.0 - chg.astype(jnp.float32))
                agg_d, elast, eage = jax.vmap(
                    partial(kernel_dispatch.delayed_grad, mode=kernel_mode),
                    in_axes=(0, 0, 0, 0, None, None, 0))(
                    ws, m_eff, pend, age, inp.stale_beta, inp.delay_delta,
                    v32)

            if aggregator == "hieavg":
                edge_models = agg_h
            elif aggregator == "delayed_grad":
                edge_models = agg_d
            elif aggregator == "t_fedavg":
                edge_models = jax.vmap(baselines.t_fedavg)(ws, dmask, v32)
            elif aggregator == "d_fedavg":
                m_eff = jnp.logical_or(dmask, r == 0)  # first round: all in
                edge_models, elast = jax.vmap(baselines.d_fedavg)(
                    ws, m_eff, elast, v32)
            elif aggregator == "fedavg":
                edge_models = jax.vmap(
                    partial(kernel_dispatch.fedavg, mode=kernel_mode))(
                    ws, v32)
            elif aggregator == "switched":
                # all three strategies are computed; the traced per-point
                # agg_sel picks one — an aggregation-mode grid batches
                # into one padded shard_map call like any data field
                edge_models = sel3(
                    inp.agg_sel, agg_h, agg_d,
                    jax.vmap(partial(kernel_dispatch.fedavg,
                                     mode=kernel_mode))(ws, v32))
            else:
                raise ValueError(f"unknown aggregator {aggregator!r}")

            new_c = (bcast_devices(edge_models), ehist, elast, eage)
            # per-edge elapsed: the slowest valid device closes the round
            # (padded slots carry dev_time 0; padded edge rounds count 0)
            el = jnp.max(jnp.where(inp.valid, dtime, 0.0), axis=1)
            el = el * (k < inp.k_valid)
            # padded edge round (k >= k_valid): carry passes through
            return passthru(k < inp.k_valid, new_c, prev_c), (dev_loss, el)

        ks = jnp.arange(K)
        rs = (t - 1) * K + ks
        (device_w, ehist, elast, eage), (dev_losses, edge_els) = jax.lax.scan(
            edge_round, (device_w, ehist, elast, eage),
            (bidx_t, dmask_t, lr_t, rs, ks, dtime_t))
        # after the sync every device slot holds its edge model
        edge_models = jax.tree.map(lambda x: x[:, 0], device_w)

        # ---- global aggregation on the (replayed) leader
        if aggregator in ("hieavg", "switched"):
            ghist = jax.lax.cond(
                t == 1,
                lambda h: hieavg.init_history(edge_models, history_dtype),
                lambda h: h, ghist)
            pw = inp.j_arr / jnp.sum(inp.j_arr)

            def coldg(w, m, h):
                return (kernel_dispatch.global_aggregate_cold(
                    w, inp.j_arr, mode=kernel_mode),
                        hieavg.update_history(h, w, m))

            def warmg(w, m, h):
                return kernel_dispatch.global_aggregate(
                    w, m, h, pw, inp.gamma0, inp.lam, normalize,
                    mode=kernel_mode)

            gagg_h, ghist = jax.lax.cond(
                t <= inp.t_cold_boot, coldg, warmg, edge_models, emask, ghist)
        if aggregator in ("delayed_grad", "switched"):
            # edges are fixed infrastructure — no churn reset at this layer
            m_eff = jnp.logical_or(emask, t == 1)
            gagg_d, glast, gage = kernel_dispatch.delayed_grad(
                edge_models, m_eff, glast, gage, inp.stale_beta,
                inp.delay_delta, inp.j_arr, mode=kernel_mode)

        if aggregator == "hieavg":
            global_w = gagg_h
        elif aggregator == "delayed_grad":
            global_w = gagg_d
        elif aggregator == "t_fedavg":
            global_w = baselines.t_fedavg(edge_models, emask, inp.j_arr)
        elif aggregator == "d_fedavg":
            m_eff = jnp.logical_or(emask, t == 1)
            global_w, glast = baselines.d_fedavg(
                edge_models, m_eff, glast, inp.j_arr)
        elif aggregator == "switched":
            global_w = sel3(inp.agg_sel, gagg_h, gagg_d,
                            kernel_dispatch.fedavg(edge_models, inp.j_arr,
                                                   mode=kernel_mode))
        else:
            global_w = kernel_dispatch.fedavg(edge_models, inp.j_arr,
                                              mode=kernel_mode)

        device_w = bcast_devices(bcast_edges(global_w))

        # ---- per-round metrics (same definitions as the legacy loop);
        # test accuracy is evaluated OUTSIDE the scan, batched over rounds.
        # The last *valid* edge round's losses, not dev_losses[-1]: trailing
        # K entries may be sweep padding.
        last_loss = jnp.take(dev_losses, inp.k_valid - 1, axis=0)
        loss = jnp.sum(last_loss * v32) / jnp.maximum(jnp.sum(v32), 1.0)
        delta = jnp.sqrt(sum(
            jnp.sum(jnp.square(a - b)) for a, b in
            zip(jax.tree.leaves(global_w), jax.tree.leaves(prev_global))))

        # ---- simulated clock: the global aggregation waits for the
        # slowest SUBMITTING edge's K-round window (all valid edges when
        # every edge straggled), plus the edge<->leader hop, plus the
        # consensus stall when L_bc does not hide inside the window (C2)
        window = jnp.sum(edge_els, axis=0)             # [N]
        valid_edge = inp.j_arr > 0
        sub = emask & valid_edge
        w_sub = jnp.max(jnp.where(sub, window, 0.0))
        w_all = jnp.max(jnp.where(valid_edge, window, 0.0))
        w = jnp.where(jnp.any(sub), w_sub, w_all)
        round_time = w + inp.edge_hop + jnp.maximum(0.0, cons_t - w)

        # padded global round (t > t_valid): carry passes through, outputs
        # repeat the final valid global model/clock with zeroed loss/delta
        t_ok = t <= inp.t_valid
        out_carry = passthru(t_ok, (device_w, ehist, elast, ghist, glast,
                                    global_w, clock + round_time,
                                    eage, gage, energy + cons_en_t),
                             prev_carry)
        return out_carry, (out_carry[5], jnp.where(t_ok, loss, 0.0),
                           jnp.where(t_ok, delta, 0.0), out_carry[6],
                           out_carry[9])

    # round-zero carry unless resuming a chunked run (the carry IS the
    # whole cross-round state — see init_engine_carry); the scanned round
    # numbers are GLOBAL (t_start-offset), so cold boot / history-init /
    # validity conditions are chunk-invariant
    if carry0 is None:
        carry0 = init_engine_carry(inp, history_dtype)
    t0 = jnp.int32(0) if t_start is None else t_start
    xs = (t0 + jnp.arange(1, T + 1), inp.batch_idx, inp.dev_masks,
          inp.edge_masks, inp.lr, inp.dev_time, inp.cons_time,
          inp.cons_energy, inp.cohort_change)
    final_carry, (globals_per_round, losses, deltas, clocks, energies) = \
        jax.lax.scan(global_round, carry0, xs)
    # test-set eval over the T round snapshots, outside the training scan.
    # lax.map (not vmap): one whole-test-set batched matmul per round with
    # round-at-a-time peak memory — vmapping all T rounds through the 9x
    # im2col intermediate is O(T * n_test * H * W * 9c) and OOMs at the
    # paper's DEFAULT sizes.
    test_x = inp.test_x[inp.seed_idx]
    test_y = inp.test_y[inp.seed_idx]
    accs = jax.lax.map(
        lambda w: cnn_accuracy_fast(w, test_x, test_y,
                                    kernel_mode=kernel_mode),
        globals_per_round)
    if with_carry:
        return (accs, losses, deltas, clocks, energies), final_carry
    return accs, losses, deltas, clocks, energies


@partial(jax.jit, static_argnames=("aggregator", "normalize",
                                   "history_dtype", "kernel_mode"))
def run_engine(inp: EngineInputs, *, aggregator: str = "hieavg",
               normalize: bool = False, history_dtype=None,
               kernel_mode: str = "auto"
               ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                          jnp.ndarray, jnp.ndarray]:
    """The standard jitted entry — see ``_engine_body`` for the contract
    (returns accuracy, loss, delta norm, simulated clock, cumulative
    consensus energy — each ``[T]``).

    Input buffers are left intact (callers may reuse ``inp``); the
    donating twin is ``run_engine_donated``.
    """
    return _engine_body(inp, aggregator=aggregator, normalize=normalize,
                        history_dtype=history_dtype, kernel_mode=kernel_mode)


@partial(jax.jit, static_argnames=("aggregator", "normalize",
                                   "history_dtype", "kernel_mode"))
def run_engine_chunk(inp: EngineInputs, carry: tuple, t_start: jnp.ndarray,
                     *, aggregator: str = "hieavg", normalize: bool = False,
                     history_dtype=None, kernel_mode: str = "auto"
                     ) -> tuple[tuple, tuple]:
    """Run a contiguous segment of global rounds and return the carry.

    ``inp`` is a ``slice_rounds`` view covering rounds ``t_start..t_start+C``
    (0-based), ``carry`` the scan state after round ``t_start`` (round zero:
    ``init_engine_carry``).  Returns ``((acc, loss, delta, clock, energy)
    each [C], new_carry)``.  ``t_start`` is TRACED, so every equal-length
    chunk of a run shares one compiled program; running the chunks back to
    back is the same per-round op sequence as one full-length scan, and
    feeding a checkpointed carry back in resumes bitwise (the carry is the
    entire cross-round state — ``BHFLSimulator.run_checkpointed`` builds
    the round-level checkpoint/resume loop on top of this).
    """
    return _engine_body(inp, aggregator=aggregator, normalize=normalize,
                        history_dtype=history_dtype, kernel_mode=kernel_mode,
                        carry0=carry, t_start=t_start, with_carry=True)


@partial(jax.jit, static_argnames=("aggregator", "normalize",
                                   "history_dtype", "kernel_mode"),
         donate_argnums=(0,))
def _run_engine_donated(hot: dict, shared: dict, *,
                        aggregator: str, normalize: bool, history_dtype,
                        kernel_mode: str):
    return _engine_body(merge_inputs(hot, shared), aggregator=aggregator,
                        normalize=normalize, history_dtype=history_dtype,
                        kernel_mode=kernel_mode)


def run_engine_donated(inp: EngineInputs, *, aggregator: str = "hieavg",
                       normalize: bool = False, history_dtype=None,
                       kernel_mode: str = "auto"
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                  jnp.ndarray, jnp.ndarray]:
    """``run_engine`` with the hot input planes DONATED to the program.

    Every ``EngineInputs`` field except the seed-major data plane
    (``SHARED_DATA_FIELDS`` — aliased across callers, never donated) is
    handed to XLA for buffer reuse, so the run does not hold the caller's
    copy of the batch-index/mask/latency planes alive next to its own
    working set.  ``inp``'s hot leaves are DELETED afterwards — callers
    must treat the inputs as consumed (``BHFLSimulator.run`` rebuilds
    them per call; the sweep runners donate per bucket the same way).
    Numerics are identical to ``run_engine`` (same traced body).
    """
    hot, shared = split_inputs(inp)
    with warnings.catch_warnings():
        # expected: the engine's outputs are tiny [T] rows, so XLA rarely
        # finds an input-output alias for the big donated planes — the
        # donation is still correct (and pays off where aliasing applies);
        # the caller-side release of the consumed inputs is the real win
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _run_engine_donated(hot, shared, aggregator=aggregator,
                                   normalize=normalize,
                                   history_dtype=history_dtype,
                                   kernel_mode=kernel_mode)


# ----------------------------------------------------------------- sweeps
# The sweep subsystem lives in ``repro.fl.sweep``: a shape-polymorphic
# planner (grids may change topology/rounds; points are grouped into shape
# buckets and padded to each bucket's maxima) plus mesh placement
# (shard_map over the data axis per bucket, vmap fallback).
# ``run_sweep``/``SweepResult`` are re-exported there and via ``repro.fl``.
