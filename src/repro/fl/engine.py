"""Fully-jitted batched BHFL simulation engine.

The legacy ``BHFLSimulator.run`` loop dispatches one jitted aggregation call
per edge per edge-round plus host-side numpy batching — every sweep point
pays per-edge dispatch and host→device transfer overhead.  This engine
compiles an ENTIRE run into one program:

  * ragged ``j_per_edge`` is padded into a dense ``[N, J_max]`` device layout
    with a boolean ``valid`` mask (padded slots carry zero aggregation
    weight and are overwritten by the edge sync every round),
  * HieAvg edge aggregation is one vmapped ``_mix_and_update`` across all N
    edges instead of N sequential calls,
  * straggler masks, batch indices, and the learning-rate schedule are
    precomputed host-side into dense arrays (``core.straggler.stack_ragged``),
  * the K edge rounds and the global aggregation are driven by nested
    ``jax.lax.scan`` — one global round is one fused XLA computation, and the
    T rounds run without returning to Python,
  * ``run_sweep`` adds a ``vmap`` sweep axis so Fig. 3-style
    multi-seed/multi-fraction grids execute as a single batched call.

The Raft chain (control plane, no model numerics) is replayed host-side
*before* the jitted run: it consumes the same RNG stream in the same order as
the legacy loop, so leader failover produces identical edge masks.

Parity with ``BHFLSimulator.run_legacy`` is tested in
``tests/test_engine_parity.py``; throughput is tracked in
``BENCH_engine.json`` (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, hieavg
from repro.core import straggler as strag
from repro.models import (cnn_accuracy_fast, cnn_loss, cnn_loss_fast,
                          init_from_specs)
from repro.optim import paper_lr

PyTree = Any


# --------------------------------------------------------------- local step
def train_epoch_body(params: PyTree, images: jnp.ndarray,
                     labels: jnp.ndarray, lr: jnp.ndarray,
                     loss_fn=cnn_loss_fast) -> tuple[PyTree, jnp.ndarray]:
    """One local epoch for all devices.  params: stacked [D, ...];
    images: [D, steps, B, H, W, 1]; labels: [D, steps, B]. Returns
    (new stacked params, mean loss per device [D]).

    scan(vmap(step)) rather than vmap(scan): one fused all-device matmul per
    step instead of D separate small ones.  The engine trains with the
    im2col conv (``cnn_loss_fast``); the legacy reference loop keeps the
    shifted-sum conv (same math, different summation order).
    """

    def step(ps, xs):
        im, lb = xs                                     # [D, B, ...]
        loss, g = jax.vmap(jax.value_and_grad(loss_fn))(ps, im, lb)
        ps = jax.tree.map(lambda w, gw: w - lr * gw, ps, g)
        return ps, loss

    images = jnp.swapaxes(images, 0, 1)                 # [steps, D, ...]
    labels = jnp.swapaxes(labels, 0, 1)
    params, losses = jax.lax.scan(step, params, (images, labels))
    return params, jnp.mean(losses, axis=0)


# jitted legacy-exact epoch (shifted-sum conv), used by run_legacy
train_epoch = jax.jit(partial(train_epoch_body, loss_fn=cnn_loss))


# ------------------------------------------------------------ dense inputs
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineInputs:
    """Everything a jitted run consumes, as dense device arrays.

    Leaves are stackable across grid points (``run_sweep`` vmaps over a
    leading point axis); gamma0/lam/t_cold_boot ride along as scalars so
    decay-factor sweeps are data, not recompiles.
    """

    train_x: jnp.ndarray      # [n_train, H, W, 1] f32
    train_y: jnp.ndarray      # [n_train] i32
    test_x: jnp.ndarray       # [n_test, H, W, 1] f32
    test_y: jnp.ndarray       # [n_test] i32
    init_w: PyTree            # global model at t=0
    batch_idx: jnp.ndarray    # [T, K, N, J, steps, B] i32 into train_x
    has_data: jnp.ndarray     # [N, J] f32 — 0 for empty-shard/padded slots
    valid: jnp.ndarray        # [N, J] bool — real device slots
    dev_masks: jnp.ndarray    # [T, K, N, J] bool submission masks
    edge_masks: jnp.ndarray   # [T, N] bool (failover already applied)
    lr: jnp.ndarray           # [T, K] f32 paper schedule
    j_arr: jnp.ndarray        # [N] f32 devices per edge (global weights)
    gamma0: jnp.ndarray       # scalar f32
    lam: jnp.ndarray          # scalar f32
    t_cold_boot: jnp.ndarray  # scalar i32


def replay_chain(sim) -> None:
    """Replay the control plane exactly as the legacy loop interleaves it:
    elect → (maybe crash the leader) → commit, once per global round.

    Mutates ``sim.chain`` and — on leader failure — ``sim.edge_masks``
    in place, identically to ``BHFLSimulator.run_legacy`` (the chain RNG
    stream is consumed in the same order, so the same leaders win).  The
    crash itself is applied at most once per simulator: a repeated
    ``run()`` replays the same failed edge instead of killing another
    leader (which would eventually lose Raft quorum).
    """
    failed_edge: Optional[int] = getattr(sim, "_failed_leader", None)
    for t in range(1, sim.s.t_global_rounds + 1):
        sim.chain.elect_leader()
        if (sim.fail_leader_at is not None and t == sim.fail_leader_at
                and failed_edge is None):
            failed_edge = sim.chain.leader
            sim.chain.fail_node(failed_edge)
            sim._failed_leader = failed_edge
        if failed_edge is not None and t >= sim.fail_leader_at:
            # only from the crash round on — a repeated replay must not
            # widen the outage to earlier rounds
            sim.edge_masks[t - 1:, failed_edge] = False
        sim.chain.commit_block(f"edges@t={t}", f"global@t={t}")


def build_inputs(sim) -> EngineInputs:
    """Precompute a ``BHFLSimulator``'s whole run into dense device arrays.

    Batch indices are sampled from a fresh ``default_rng(seed)`` in the same
    (round, device) order as the legacy loop's per-round ``_epoch_batches``,
    so a fresh legacy instance and a fresh engine instance see identical
    batches.  Also replays the Raft chain (see ``replay_chain``).
    """
    s = sim.s
    T, K, N = s.t_global_rounds, s.k_edge_rounds, sim.N
    steps, bs = sim.steps, s.batch_size

    replay_chain(sim)

    dense_dev, valid = strag.stack_ragged(sim.dev_masks)
    J = valid.shape[1]
    dev_masks = dense_dev[:T * K].reshape(T, K, N, J)
    edge_masks = np.asarray(sim.edge_masks[:T], dtype=bool)

    # batch indices in legacy order: per edge-round, per device
    rng = np.random.default_rng(sim.seed)
    R = T * K
    flat_idx = np.zeros((R, sim.D, steps, bs), np.int32)
    flat_has = np.zeros((sim.D,), np.float32)
    for r in range(R):
        for d, idx in enumerate(sim.device_idx):
            if len(idx) == 0:
                continue
            flat_idx[r, d] = rng.choice(idx, size=(steps, bs), replace=True)
            flat_has[d] = 1.0
    batch_idx = np.zeros((R, N, J, steps, bs), np.int32)
    has_data = np.zeros((N, J), np.float32)
    d = 0
    for e in range(N):
        for j in range(sim.j_per_edge[e]):
            batch_idx[:, e, j] = flat_idx[:, d]
            has_data[e, j] = flat_has[d]
            d += 1

    lr = paper_lr(jnp.arange(R), s.lr0, s.lr_decay).reshape(T, K)
    init_w = init_from_specs(sim.specs, jax.random.key(sim.seed))

    return EngineInputs(
        train_x=jnp.asarray(sim.train_x), train_y=jnp.asarray(sim.train_y),
        test_x=sim.test_x, test_y=sim.test_y, init_w=init_w,
        batch_idx=jnp.asarray(batch_idx.reshape(T, K, N, J, steps, bs)),
        has_data=jnp.asarray(has_data), valid=jnp.asarray(valid),
        dev_masks=jnp.asarray(dev_masks), edge_masks=jnp.asarray(edge_masks),
        lr=lr, j_arr=jnp.asarray(sim.j_per_edge, jnp.float32),
        gamma0=jnp.float32(s.gamma0), lam=jnp.float32(s.lam),
        t_cold_boot=jnp.int32(s.t_cold_boot))


# ------------------------------------------------------------- jitted run
@partial(jax.jit, static_argnames=("aggregator", "normalize"))
def run_engine(inp: EngineInputs, *, aggregator: str = "hieavg",
               normalize: bool = False
               ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One whole BHFL run as a single compiled program.

    Returns per-global-round (accuracy [T], mean local loss [T],
    global-model round-to-round delta norm [T]).
    """
    T, K, N, J = inp.dev_masks.shape
    steps, bs = inp.batch_idx.shape[-2:]
    D = N * J
    v32 = inp.valid.astype(jnp.float32)
    hd = inp.has_data

    def bcast_edges(tree):   # [...] global -> [N, ...]
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (N,) + x.shape), tree)

    def bcast_devices(tree):  # [N, ...] edge models -> [N, J, ...]
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[:, None], (N, J) + x.shape[1:]),
            tree)

    def flat(tree):           # [N, J, ...] -> [N*J, ...]
        return jax.tree.map(lambda x: x.reshape((D,) + x.shape[2:]), tree)

    def unflat(tree):
        return jax.tree.map(lambda x: x.reshape((N, J) + x.shape[1:]), tree)

    def global_round(carry, xs):
        device_w, ehist, elast, ghist, glast, prev_global = carry
        t, bidx_t, dmask_t, emask, lr_t = xs

        # ---- K edge rounds: local epoch + per-edge aggregation + sync
        def edge_round(c, xs_k):
            device_w, ehist, elast = c
            bidx, dmask, lr, r = xs_k   # [N,J,steps,B], [N,J], scalar, scalar

            x = inp.train_x[bidx] * hd[:, :, None, None, None, None, None]
            y = jnp.where(hd[:, :, None, None] > 0, inp.train_y[bidx], 0)
            pflat, loss = train_epoch_body(
                flat(device_w), x.reshape((D, steps, bs) + x.shape[4:]),
                y.reshape(D, steps, bs), lr)
            ws = unflat(pflat)
            dev_loss = loss.reshape(N, J)

            if aggregator == "hieavg":
                ehist = jax.lax.cond(
                    r == 0, lambda h: hieavg.init_history_batched(ws),
                    lambda h: h, ehist)

                def cold(w, m, h):
                    return (hieavg.edge_aggregate_cold_batched(w, inp.valid),
                            hieavg.update_history_batched(h, w, m))

                def warm(w, m, h):
                    return hieavg.edge_aggregate_batched(
                        w, m, h, inp.valid, inp.gamma0, inp.lam, normalize)

                edge_models, ehist = jax.lax.cond(
                    t <= inp.t_cold_boot, cold, warm, ws, dmask, ehist)
            elif aggregator == "t_fedavg":
                edge_models = jax.vmap(baselines.t_fedavg)(ws, dmask, v32)
            elif aggregator == "d_fedavg":
                m_eff = jnp.logical_or(dmask, r == 0)  # first round: all in
                edge_models, elast = jax.vmap(baselines.d_fedavg)(
                    ws, m_eff, elast, v32)
            elif aggregator == "fedavg":
                edge_models = jax.vmap(baselines.fedavg)(ws, v32)
            else:
                raise ValueError(f"unknown aggregator {aggregator!r}")

            return (bcast_devices(edge_models), ehist, elast), dev_loss

        rs = (t - 1) * K + jnp.arange(K)
        (device_w, ehist, elast), dev_losses = jax.lax.scan(
            edge_round, (device_w, ehist, elast),
            (bidx_t, dmask_t, lr_t, rs))
        # after the sync every device slot holds its edge model
        edge_models = jax.tree.map(lambda x: x[:, 0], device_w)

        # ---- global aggregation on the (replayed) leader
        if aggregator == "hieavg":
            ghist = jax.lax.cond(
                t == 1, lambda h: hieavg.init_history(edge_models),
                lambda h: h, ghist)
            pw = inp.j_arr / jnp.sum(inp.j_arr)

            def coldg(w, m, h):
                return (hieavg.global_aggregate_cold(w, inp.j_arr),
                        hieavg.update_history(h, w, m))

            def warmg(w, m, h):
                return hieavg.aggregate(w, m, h, pw, inp.gamma0, inp.lam,
                                        normalize)

            global_w, ghist = jax.lax.cond(
                t <= inp.t_cold_boot, coldg, warmg, edge_models, emask, ghist)
        elif aggregator == "t_fedavg":
            global_w = baselines.t_fedavg(edge_models, emask, inp.j_arr)
        elif aggregator == "d_fedavg":
            m_eff = jnp.logical_or(emask, t == 1)
            global_w, glast = baselines.d_fedavg(
                edge_models, m_eff, glast, inp.j_arr)
        else:
            global_w = baselines.fedavg(edge_models, inp.j_arr)

        device_w = bcast_devices(bcast_edges(global_w))

        # ---- per-round metrics (same definitions as the legacy loop);
        # test accuracy is evaluated OUTSIDE the scan, batched over rounds
        loss = jnp.sum(dev_losses[-1] * v32) / jnp.maximum(jnp.sum(v32), 1.0)
        delta = jnp.sqrt(sum(
            jnp.sum(jnp.square(a - b)) for a, b in
            zip(jax.tree.leaves(global_w), jax.tree.leaves(prev_global))))
        return (device_w, ehist, elast, ghist, glast, global_w), \
            (global_w, loss, delta)

    edge0 = bcast_edges(inp.init_w)
    dev0 = bcast_devices(edge0)
    carry0 = (dev0,
              hieavg.init_history_batched(dev0),       # overwritten at r==0
              jax.tree.map(jnp.zeros_like, dev0),      # d_fedavg last stores
              hieavg.init_history(edge0),              # overwritten at t==1
              jax.tree.map(jnp.zeros_like, edge0),
              inp.init_w)
    xs = (jnp.arange(1, T + 1), inp.batch_idx, inp.dev_masks,
          inp.edge_masks, inp.lr)
    _, (globals_per_round, losses, deltas) = jax.lax.scan(
        global_round, carry0, xs)
    # test-set eval over the T round snapshots, outside the training scan.
    # lax.map (not vmap): one whole-test-set batched matmul per round with
    # round-at-a-time peak memory — vmapping all T rounds through the 9x
    # im2col intermediate is O(T * n_test * H * W * 9c) and OOMs at the
    # paper's DEFAULT sizes.
    accs = jax.lax.map(
        lambda w: cnn_accuracy_fast(w, inp.test_x, inp.test_y),
        globals_per_round)
    return accs, losses, deltas


# ----------------------------------------------------------------- sweeps
@dataclasses.dataclass
class SweepResult:
    """Batched trajectories for a grid of runs (leading axis = grid point)."""
    points: list              # (overrides dict, seed) per grid point
    accuracy: np.ndarray      # [P, T]
    loss: np.ndarray          # [P, T]
    grad_norm: np.ndarray     # [P, T]
    sim_latency: np.ndarray   # [P]
    blocks: np.ndarray        # [P]


def run_sweep(setting, seeds=(0,), *, overrides: Optional[list] = None,
              aggregator: str = "hieavg",
              device_stragglers: str = "temporary",
              edge_stragglers: str = "temporary",
              normalize: bool = False, **sim_kw) -> SweepResult:
    """Fig. 3-style grids as ONE batched call.

    ``overrides`` is a list of ``BHFLSetting`` field-override dicts (e.g.
    ``[{"straggler_frac": 0.2}, {"straggler_frac": 0.4}]``), crossed with
    ``seeds``.  Every grid point is precomputed host-side into
    ``EngineInputs``; the stacked inputs run as a single
    ``vmap(run_engine)`` — no per-point dispatch or re-trace.  All points
    must agree on shape-determining fields (rounds, topology, image size);
    straggler fractions/kinds, gamma/lambda, cold-boot length, and seeds may
    vary freely.
    """
    from repro.fl.simulator import BHFLSimulator  # lazy: avoid import cycle

    points = [(ov, seed) for ov in (overrides or [{}]) for seed in seeds]
    sims = [BHFLSimulator(dataclasses.replace(setting, **ov), aggregator,
                          device_stragglers, edge_stragglers,
                          normalize=normalize, seed=seed, **sim_kw)
            for ov, seed in points]
    inputs = [build_inputs(s) for s in sims]
    shapes = [jax.tree.map(jnp.shape, i) for i in inputs]
    if any(s != shapes[0] for s in shapes[1:]):
        raise ValueError("run_sweep grid points must share all array shapes "
                         "(rounds, topology, image size, batch schedule)")
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *inputs)
    accs, losses, deltas = jax.vmap(
        lambda i: run_engine(i, aggregator=aggregator, normalize=normalize)
    )(stacked)
    return SweepResult(
        points=points,
        accuracy=np.asarray(accs), loss=np.asarray(losses),
        grad_norm=np.asarray(deltas),
        sim_latency=np.asarray([s.paper_latency() for s in sims]),
        blocks=np.asarray([len(s.chain.blocks) - 1 for s in sims]))
