"""Chaos plane — declarative fault injection for BHFL deployments.

The paper's premise is tolerance of stragglers *and* single points of
failure, but the repro's only fault used to be one scripted
``fail_leader_at`` leader crash that masked an edge out forever.  This
module turns "decentralized and straggler-tolerant" into a measurable
claim: a declarative :class:`FaultSpec` (crash–recover processes, bursts,
message loss, bounded quorum-stall policy) is compiled once per deployment
into a :class:`FaultSchedule` of host-side per-round event planes, drawn
from the dedicated ``"faults"`` stream of the ``core.rng`` registry so
fault injection never perturbs data/batch/latency draws.

Fault processes (all off by default — an all-zero spec compiles the inert
schedule without consuming any randomness):

  * **Edge crash–recover** (``edge_fail_rate``/``edge_recover_rate``): a
    two-state Markov process per edge per *global round* (rate = 1/MTBF
    resp. 1/MTTR in rounds).  A down edge neither submits to the global
    aggregation (its ``edge_masks`` row is cleared — HieAvg's historical
    estimator spans the outage exactly as it does for stragglers, the
    ``miss_count`` axis keeps counting) nor participates in consensus
    (its chain node is failed for those rounds).  On recovery the edge
    rejoins from the latest committed global model: the engine broadcasts
    the global model to every slot each round, so rejoining is the
    existing sync, not a special path.
  * **Chain-validator churn** (``val_fail_rate``/``val_recover_rate``): an
    independent Markov process over consensus *attempt ticks* — the
    ``[T, max_stall_rounds + 1]`` grid of (round, stall attempt) slots —
    failing/recovering chain validators without touching training.  This
    is what makes alive counts, latency, and energy vary over rounds, and
    what lets a stalled round recover quorum mid-stall.
  * **Correlated device-outage bursts** (``burst_prob``/``burst_frac``):
    per (global round, edge), a burst takes ``ceil(burst_frac * J_e)``
    random devices out for the whole round (all K edge rounds) — the
    rack-switch / cell-outage failure mode iid masks cannot express.
  * **Submission message loss** (``msg_loss_prob``): iid per device
    edge-round submission and per edge global submission.  A lost message
    is indistinguishable from a straggler miss to the aggregator (the
    deadline passes without it), which is exactly the paper's model.
  * **Leader crash** (``leader_crash_round``): the paper's original
    single-point-of-failure drill, re-expressed as a one-event schedule —
    ``BHFLSimulator(fail_leader_at=t)`` routes through here and is
    parity-pinned bitwise against the pre-chaos behaviour.

Below-quorum policy: with ``max_stall_rounds=0`` a below-quorum round
raises immediately (the pre-chaos semantics, zoo-wide).  With
``max_stall_rounds=S > 0`` the round *stalls*: each retry waits
``stall_backoff * 2**attempt`` simulated seconds (accumulated into that
round's ``cons_time`` draw, i.e. counted by the engine's traced clock as
C2 consensus stall), re-applies the next validator-churn attempt tick
(recoveries may restore quorum), and re-runs the protocol round; only
after S failed retries does the ``RuntimeError`` propagate.

Everything here is host-side numpy: schedules are *data* consumed by
``fl.engine.build_inputs``/``replay_chain``, so every fault-rate field is
a data-batched sweep field (``fl.sweep.BATCHED_FIELDS``) and a fault-rate
x consensus grid compiles as ONE padded call.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core import rng as rng_streams

#: Draw order inside :func:`compile_schedule` — fixed and append-only so a
#: spec that enables a later process never re-keys an earlier one's draws.
_DRAW_ORDER = ("edge_process", "validator_process", "bursts", "msg_loss")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative fault model for one deployment (all processes off by
    default).  Field semantics match the ``BHFLSetting`` fault fields —
    ``from_setting`` lifts them — plus the ``leader_crash_round`` one-event
    drill; rates are per-round/tick Markov transition probabilities."""
    edge_fail_rate: float = 0.0
    edge_recover_rate: float = 0.0
    val_fail_rate: float = 0.0
    val_recover_rate: float = 0.0
    burst_prob: float = 0.0
    burst_frac: float = 0.5
    msg_loss_prob: float = 0.0
    leader_crash_round: Optional[int] = None
    max_stall_rounds: int = 0
    stall_backoff: float = 0.5

    def __post_init__(self):
        for name in ("edge_fail_rate", "edge_recover_rate", "val_fail_rate",
                     "val_recover_rate", "burst_prob", "burst_frac",
                     "msg_loss_prob"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"FaultSpec.{name} is a probability, got {v}")
        if self.max_stall_rounds < 0:
            raise ValueError("max_stall_rounds must be >= 0, got "
                             f"{self.max_stall_rounds}")
        if self.stall_backoff < 0.0:
            raise ValueError("stall_backoff must be >= 0, got "
                             f"{self.stall_backoff}")
        if self.leader_crash_round is not None \
                and self.leader_crash_round < 1:
            raise ValueError("leader_crash_round is a 1-based global round, "
                             f"got {self.leader_crash_round}")

    @property
    def any_faults(self) -> bool:
        """True when any stochastic fault process is enabled (the leader
        crash drill alone keeps the schedule draw-free)."""
        return any(r > 0.0 for r in (
            self.edge_fail_rate, self.val_fail_rate, self.burst_prob,
            self.msg_loss_prob))

    @classmethod
    def from_setting(cls, setting,
                     leader_crash_round: Optional[int] = None) -> "FaultSpec":
        """Lift a ``BHFLSetting``'s fault fields into a spec (how the
        simulator and the sweep fabric construct fault planes — every
        field here is a data-batched sweep field)."""
        return cls(
            edge_fail_rate=setting.edge_fail_rate,
            edge_recover_rate=setting.edge_recover_rate,
            val_fail_rate=setting.val_fail_rate,
            val_recover_rate=setting.val_recover_rate,
            burst_prob=setting.burst_prob,
            burst_frac=setting.burst_frac,
            msg_loss_prob=setting.msg_loss_prob,
            leader_crash_round=leader_crash_round,
            max_stall_rounds=setting.max_stall_rounds,
            stall_backoff=setting.stall_backoff)


@dataclasses.dataclass
class FaultSchedule:
    """Compiled per-round event planes for one deployment (host numpy).

    The schedule is pure data: compiling it twice from the same (spec,
    geometry, seed) is bitwise identical, so repeated ``run()`` calls and
    checkpoint resumes replay the exact same faults.  Array contract:

      * ``edge_down [T, N]`` — edge unavailable during global round t+1
        (1-based round t ↔ row t-1): masked out of the global aggregation
        AND failed as a chain node for that round.
      * ``val_down [T, S+1, N]`` — validator-churn state at (round,
        consensus-attempt) tick; attempt 0 is the round's normal try,
        attempts 1..S its stall retries.  The process ticks through every
        cell in row-major order whether or not the attempt happens — what
        keeps the plane precompilable and replays bitwise-repeatable.
      * ``dev_drop [T*K, N, J]`` — device submission lost this edge round
        (burst ∪ message loss), folded into the engine's submission masks
        before the latency draws so a dropped device is deadline-capped
        exactly like a straggler.
      * ``edge_msg_drop [T, N]`` — the edge's global submission was lost
        (trained fine, message dropped): cleared from ``edge_masks`` only,
        the chain node stays alive.
    """
    spec: FaultSpec
    edge_down: np.ndarray       # [T, N] bool
    val_down: np.ndarray        # [T, S+1, N] bool
    dev_drop: np.ndarray        # [T*K, N, J] bool
    edge_msg_drop: np.ndarray   # [T, N] bool

    @property
    def inert(self) -> bool:
        """True when no plane carries any event (the no-fault fast path —
        ``build_inputs`` skips mask folding entirely)."""
        return not (self.edge_down.any() or self.val_down.any()
                    or self.dev_drop.any() or self.edge_msg_drop.any())

    def availability_summary(self) -> dict:
        """Per-process downtime fractions (diagnostics / bench reporting)."""
        return {
            "edge_down_frac": float(self.edge_down.mean()),
            "val_down_frac": float(self.val_down[:, 0, :].mean()),
            "dev_drop_frac": float(self.dev_drop.mean()),
            "edge_msg_drop_frac": float(self.edge_msg_drop.mean()),
        }


def _markov_down(rng: np.random.Generator, steps: int, n: int,
                 fail_rate: float, recover_rate: float) -> np.ndarray:
    """``[steps, n]`` down-state plane of n independent two-state Markov
    chains started all-up, one transition draw per step (row 0 is the
    state after the first transition)."""
    u = rng.random((steps, n))
    down = np.zeros((steps, n), dtype=bool)
    state = np.zeros(n, dtype=bool)
    for t in range(steps):
        state = np.where(state, u[t] >= recover_rate, u[t] < fail_rate)
        down[t] = state
    return down


def compile_schedule(spec: FaultSpec, *, t_rounds: int, k_rounds: int,
                     n_edges: int, j_per_edge: list, seed: int
                     ) -> FaultSchedule:
    """Compile a spec into per-round event planes for one deployment.

    All randomness comes from the deployment's ``"faults"`` stream
    (``core.rng``), drawn in the fixed ``_DRAW_ORDER``; processes whose
    rates are zero draw nothing, so enabling one process never re-keys
    another and the all-zero spec is draw-free (bitwise parity of the
    ``fail_leader_at`` drill with the pre-chaos path).  ``j_per_edge``
    slots past an edge's real device count are never dropped (they carry
    zero aggregation weight anyway).
    """
    T, K, N = t_rounds, k_rounds, n_edges
    J = max(j_per_edge) if j_per_edge else 0
    S = spec.max_stall_rounds
    rng = rng_streams.stream_rng(seed, "faults")

    edge_down = np.zeros((T, N), dtype=bool)
    if spec.edge_fail_rate > 0.0:
        edge_down = _markov_down(rng, T, N, spec.edge_fail_rate,
                                 spec.edge_recover_rate)

    val_down = np.zeros((T, S + 1, N), dtype=bool)
    if spec.val_fail_rate > 0.0:
        val_down = _markov_down(rng, T * (S + 1), N, spec.val_fail_rate,
                                spec.val_recover_rate
                                ).reshape(T, S + 1, N)

    dev_drop = np.zeros((T * K, N, J), dtype=bool)
    if spec.burst_prob > 0.0:
        hit = rng.random((T, N)) < spec.burst_prob          # [T, N]
        u = rng.random((T, N, J))                           # victim scores
        # per (round, edge) burst: ceil(burst_frac * J_e) distinct random
        # REAL devices go out for the whole round (all K edge rounds) —
        # the lowest-scoring slots among the edge's real device count
        for e, j_e in enumerate(j_per_edge):
            n_out = math.ceil(spec.burst_frac * j_e)
            if n_out == 0:
                continue
            order = np.argsort(u[:, e, :j_e], axis=-1)      # [T, j_e] perms
            out = np.zeros((T, J), dtype=bool)
            np.put_along_axis(out[:, :j_e], order[:, :n_out], True, axis=1)
            out &= hit[:, e:e + 1]
            dev_drop[:, e, :] |= np.repeat(out, K, axis=0)[:T * K]
    if spec.msg_loss_prob > 0.0:
        dev_drop |= rng.random((T * K, N, J)) < spec.msg_loss_prob

    edge_msg_drop = np.zeros((T, N), dtype=bool)
    if spec.msg_loss_prob > 0.0:
        edge_msg_drop = rng.random((T, N)) < spec.msg_loss_prob

    return FaultSchedule(spec=spec, edge_down=edge_down, val_down=val_down,
                         dev_drop=dev_drop, edge_msg_drop=edge_msg_drop)


def apply_chain_availability(chain, want_down: np.ndarray,
                             pinned_down: Optional[set] = None) -> None:
    """Diff-apply a desired down-set onto a ``ConsensusChain``'s alive mask
    via its ``fail_node``/``recover_node`` membership interface.

    ``pinned_down`` nodes (the leader-crash drill's permanent casualty)
    stay failed no matter what the churn planes say.  Recovering through
    ``recover_node`` (not by writing ``.alive``) keeps the chain's
    leader-invalidation bookkeeping honest — the wiring that used to be
    dead code.
    """
    pinned = pinned_down or set()
    for i in range(chain.n):
        down = bool(want_down[i]) or i in pinned
        if down and chain.alive[i]:
            chain.fail_node(i)
        elif not down and not chain.alive[i]:
            chain.recover_node(i)


def stalled_round(chain, t: int, schedule: FaultSchedule,
                  pinned_down: Optional[set] = None,
                  crash_leader: bool = False
                  ) -> tuple[float, float, int, Optional[int]]:
    """Run one consensus round (elect → optional leader crash → commit)
    under the schedule's bounded quorum-stall policy.

    Attempt 0 applies the round's normal validator tick; a below-quorum
    ``RuntimeError`` then triggers up to ``spec.max_stall_rounds`` stall
    retries, each adding ``stall_backoff * 2**attempt`` seconds of backoff
    and re-applying the next attempt tick (validator recoveries can
    restore quorum mid-stall) before re-running the whole protocol round.
    With ``max_stall_rounds=0`` the first failure propagates — exactly the
    pre-chaos immediate-raise semantics, for every protocol in the zoo.

    Returns ``(elapsed_s, energy_j, stall_attempts, crashed_leader)``:
    total round latency including backoff, the chain's energy delta, how
    many retries were consumed, and the leader id crashed by the drill
    (None unless ``crash_leader``).
    """
    spec = schedule.spec
    S = spec.max_stall_rounds
    pinned = set(pinned_down or ())
    e0 = chain.energy
    stall = 0.0
    crashed: Optional[int] = None
    for attempt in range(S + 1):
        want_down = schedule.edge_down[t - 1] | schedule.val_down[t - 1,
                                                                  attempt]
        apply_chain_availability(chain, want_down, pinned)
        try:
            _, t_elect = chain.elect_leader()
            if crash_leader and crashed is None:
                crashed = chain.leader
                chain.fail_node(crashed)
                pinned.add(crashed)
            _, t_commit = chain.commit_block(f"edges@t={t}",
                                             f"global@t={t}")
            return (stall + t_elect + t_commit, chain.energy - e0,
                    attempt, crashed)
        except RuntimeError as err:
            if attempt == S:
                if S == 0:
                    raise    # immediate-raise semantics: the protocol's own
                    #          quorum error propagates unchanged
                raise RuntimeError(
                    f"consensus stalled below quorum at global round {t} "
                    f"for {S} retry attempt(s) (max_stall_rounds={S}); "
                    f"{chain.n_alive()}/{chain.n} validators alive"
                    ) from err
            stall += spec.stall_backoff * (2.0 ** attempt)
    raise AssertionError("unreachable")  # pragma: no cover
