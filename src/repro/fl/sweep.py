"""Sweep fabric — shape-bucketed sweep planner, sharded over the mesh.

The paper's headline claims are *grids*: convergence vs. straggler fraction
(Fig. 3), non-IID skew (Fig. 4), topology (N edges x J devices x K edge
rounds), consensus latency.  PR 1's ``run_sweep`` could only vmap grids
whose points agreed on every array shape; PR 2 padded every point to the
single grid maximum (one compiled call, but fig3's mixed J/N/K grid paid
several-fold padding compute); this PR buckets.

The module is a three-layer subsystem:

  Planner   ``plan_sweep`` classifies override fields (batchable / paddable
            / unsupported-with-a-clear-error), groups grid points into a
            small number of *shape buckets* — compatible ``t/k/n/j/steps``
            maxima chosen by a greedy padding-waste heuristic
            (``_bucket_points``) — and builds every point's
            ``EngineInputs`` padded to its *bucket's* maxima, stacked along
            a leading point axis per bucket.  Padded extents are numeric
            no-ops inside ``run_engine``; each point's real extents ride
            along as ``t_valid``/``k_valid``/``n_valid``/``s_valid``.
            The data plane (train/test/init, ``SHARED_DATA_FIELDS``) is
            *seed-deduped*: distinct-seed datasets are stacked once along
            a ``[n_seeds]`` axis shared by every bucket, and each point
            gathers its own row by ``seed_idx`` inside the engine — a
            10-seed confidence grid holds the distinct-seed count in
            device memory, never one dataset copy per point.

  Placement ``execute_plan`` runs each bucket as one compiled call: the
            stacked point axis shards across the mesh ``data`` axis with
            ``shard_map`` (``launch.sharding.SWEEP_RULES`` via
            ``sweep_spec``), vmapping within each shard; the data plane is
            replicated (``sweep_data_spec`` / vmap ``in_axes=None``).  The
            same autoscaling contract as the weight shardings applies per
            bucket: if a bucket's point count does not divide a >1 mesh
            axis, that bucket runs as a single-device ``vmap`` instead of
            failing to lower.  Per-bucket outputs are merged back into one
            ``[P, T_max]`` stack in original point order (rows from a
            narrower bucket extend by the engine's own tail convention:
            accuracy/clock repeat the final value, loss/grad are 0).

  Callers   ``run_sweep`` (= ``plan_sweep`` + ``run_plan``) is the
            ``BHFLSimulator``-facing wrapper returning a ``SweepResult``.
            benchmarks/fig3_sweeps.py, fig4_heterogeneity.py, and the
            examples drive it; ``SweepPlan.describe()`` renders the chosen
            bucket plan.  tests/test_sweep_fabric.py pins every padded,
            bucketed, sharded point to a standalone ``run_engine`` run.

Invariants (see docs/ARCHITECTURE.md §Sweep):
  * every grid point lands in exactly one bucket; merged outputs are in
    original point order regardless of bucketing,
  * bucketing never changes numerics — only padding extents differ, and
    padding is inert by the engine contract,
  * at most ``max_buckets`` compiled programs per plan (default 4), and
    voluntary merges keep total padded compute within ``bucket_waste``
    of the no-padding ideal,
  * the data plane rows are distinct seeds in first-appearance order; all
    buckets alias the SAME device buffers.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.configs.bhfl_cnn import BHFLSetting
from repro.fl.engine import (AGG_SEL, SHARED_DATA_FIELDS, EngineInputs,
                             build_inputs, merge_inputs, run_engine,
                             split_inputs, train_epoch_body)
from repro.kernels.dispatch import resolve_kernel_mode
from repro.models import cnn_specs
from repro.launch.mesh import make_sweep_mesh
from repro.launch.sharding import sweep_data_spec, sweep_spec

# ------------------------------------------------------- field classification
#: Fields a grid may vary freely: they only change *data* (schedules, decay
#: scalars, batch indices, per-round latency draws), never array shapes.
#: The latency-fabric fields (lm_device/lp_device/lm_edge/link_latency/
#: consensus_mult) batch because ``build_inputs`` bakes them into the
#: ``dev_time``/``cons_time``/``edge_hop`` planes of ``EngineInputs`` —
#: a consensus-latency x topology x K grid is ONE compiled call.  The
#: consensus-zoo fields (``consensus``/``n_shards``) batch the same way:
#: the protocol only changes the host-side chain replay feeding the
#: ``cons_time``/``cons_energy`` planes (unlike ``aggregation``, which
#: needs the traced "switched" program), so a mixed raft/pofel/sharded
#: grid is pure data.
#: The fault-plane fields (``edge_fail_rate`` … ``stall_backoff``) batch
#: for the same reason as the consensus zoo: faults only change host-side
#: planes — the submission/edge masks and the replayed chain's
#: ``cons_time``/``cons_energy`` draws — never array shapes, so an
#: "accuracy vs fault rate x consensus protocol" degradation grid is ONE
#: padded call (see ``repro.fl.faults`` and benchmarks/bench_faults.py).
BATCHED_FIELDS = frozenset({
    "straggler_frac", "gamma0", "lam", "t_cold_boot", "classes_per_device",
    "lr0", "lr_decay", "permanent_stop_round", "seed",
    "lm_device", "lp_device", "lm_edge", "link_latency", "consensus_mult",
    "consensus", "n_shards",
    "staleness_discount", "delay_delta",
    "edge_fail_rate", "edge_recover_rate", "val_fail_rate",
    "val_recover_rate", "burst_prob", "burst_frac", "msg_loss_prob",
    "max_stall_rounds", "stall_backoff",
})

#: Pseudo-field accepted in override dicts (NOT a ``BHFLSetting`` field):
#: the per-point aggregation strategy.  A single-valued grid plans as that
#: aggregator; a mixed grid plans as the engine's traced ``"switched"``
#: program — HieAvg-vs-delayed-gradient(-vs-FedAvg) is then ONE padded
#: shard_map call, selected per point by the batched ``agg_sel`` scalar.
AGGREGATION_FIELD = "aggregation"

#: Aggregators the traced "switched" engine can mix in one program (the
#: ``engine.AGG_SEL`` encoding); other aggregators are single-valued-only.
SWITCHABLE_AGGREGATORS = tuple(sorted(AGG_SEL))

_ALL_AGGREGATORS = ("hieavg", "t_fedavg", "d_fedavg", "delayed_grad",
                    "fedavg")

#: Fields that change array shapes but that the planner absorbs by padding
#: every point to its shape bucket's maximum.
PADDED_FIELDS = frozenset({
    "n_edges", "j_per_edge", "k_edge_rounds", "t_global_rounds",
})

#: Shape-defining fields padding cannot absorb (they change the model or
#: data geometry itself) — swept values get a clear error naming the field.
UNSUPPORTED_FIELDS = frozenset({
    "image_hw", "cnn_c1", "cnn_c2", "n_classes", "batch_size",
})


def _validate_overrides(overrides: list[dict]) -> None:
    setting_fields = {f.name for f in dataclasses.fields(BHFLSetting)}
    for ov in overrides:
        for name in ov:
            if name == AGGREGATION_FIELD:
                if ov[name] not in _ALL_AGGREGATORS:
                    raise ValueError(
                        f"run_sweep: unknown aggregation {ov[name]!r}; "
                        f"known aggregators: {_ALL_AGGREGATORS}")
                continue
            if name not in setting_fields:
                raise ValueError(
                    f"run_sweep: {name!r} is not a BHFLSetting field "
                    f"(known fields: {sorted(setting_fields)})")
            if name in UNSUPPORTED_FIELDS:
                raise ValueError(
                    f"run_sweep cannot sweep {name!r}: it changes the "
                    "model/data geometry, which padding cannot absorb. "
                    "Fix it across the grid (pass it via the base setting) "
                    "or run separate sweeps per value. Sweepable shape "
                    f"fields: {sorted(PADDED_FIELDS)}; data fields: "
                    f"{sorted(BATCHED_FIELDS)}.")
            # remaining fields are BATCHED or PADDED — both fine.


# ------------------------------------------------------------ shape buckets
# The seed-major data plane (``SHARED_DATA_FIELDS``, defined next to
# ``EngineInputs`` in ``repro.fl.engine`` and re-exported here): ONE
# ``[n_seeds, ...]`` stack shared by every bucket (vmap ``in_axes=None`` /
# shard_map replicated, never donated), gathered per point by ``seed_idx``
# inside the engine — never stacked along the point axis.

_SHAPE_KEYS = ("t", "k", "n", "j", "steps")


def _vol(ext: dict) -> int:
    """Padded-compute proxy for one point at extents ``ext``: training
    work scales with rounds x devices x steps = t*k*(n*j)*steps.

    Still the unit of ``padding_stats()``/``point_volume`` (a pure FLOP
    account, comparable across plans); the bucketing decisions themselves
    use measured step times by default (``_measured_cost_fn``).
    """
    return ext["t"] * ext["k"] * ext["n"] * ext["j"] * ext["steps"]


#: Measured wall seconds of one vmapped train step, keyed
#: (geometry, kernel_mode) -> {stacked device count D -> seconds}.
#: Module-level so repeated plans (figures re-planning the same grids)
#: pay each (geometry, D) compile-and-time exactly once per process.
_STEP_TIME_CACHE: dict[tuple, dict[int, float]] = {}


def _measured_step_time(d: int, geom: tuple) -> float:
    """Measured seconds for ONE train step over ``d`` stacked devices.

    ``geom`` = (image_hw, batch_size, c1, c2, n_classes, kernel_mode) —
    the grid-constant geometry (``plan_sweep`` rejects grids that vary
    it).  First query per (geom, d) runs one warm-up call of the
    engine's actual inner step (``train_epoch_body``: fwd + bwd + SGD
    update on zero data, through the plan's kernel path) to compile,
    then times two more and keeps the best; later queries hit the cache.

    The returned cost is forced strictly increasing in ``d`` (running
    max over cached smaller counts, plus a tiny ``1 + 1e-6·d`` tilt) so
    a merge envelope never *measures* cheaper than its members — timing
    noise would otherwise make bucketing non-deterministic.
    """
    times = _STEP_TIME_CACHE.setdefault(geom, {})
    if d not in times:
        hw, bs, c1, c2, n_classes, kernel_mode = geom
        specs = cnn_specs(hw, 1, n_classes, c1, c2)
        params = {k: jnp.zeros((d,) + sp.shape, jnp.float32)
                  for k, sp in specs.items()}
        images = jnp.zeros((d, 1, bs, hw, hw, 1), jnp.float32)
        labels = jnp.zeros((d, 1, bs), jnp.int32)
        lr = jnp.float32(0.01)
        fn = jax.jit(functools.partial(train_epoch_body,
                                       kernel_mode=kernel_mode))
        jax.block_until_ready(fn(params, images, labels, lr))  # compile
        best = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(params, images, labels, lr))
            best = min(best, time.perf_counter() - t0)
        times[d] = best
    mono = max(t for dd, t in times.items() if dd <= d)
    return mono * (1.0 + 1e-6 * d)


def _measured_cost_fn(geom: tuple):
    """Bucketing cost: rounds x measured per-step seconds at D = n·j."""

    def cost(ext: dict) -> float:
        return (ext["t"] * ext["k"] * ext["steps"]
                * _measured_step_time(ext["n"] * ext["j"], geom))

    return cost


def _bucket_points(extents: list[dict], max_buckets: int,
                   bucket_waste: float, cost_fn=_vol) -> list[dict]:
    """Group points into shape buckets under a padding-waste heuristic.

    Greedy agglomerative merge: start with one bucket per distinct extent
    tuple (identical shapes are free to share), then repeatedly merge the
    pair whose elementwise-max envelope adds the least padded compute.  A
    merge is *forced* while the bucket count exceeds ``max_buckets`` (the
    compiled-program budget) and *voluntary* while total padded compute
    stays within ``bucket_waste`` x the no-padding ideal — fewer compiles
    for bounded waste.  ``cost_fn(ext)`` prices one point padded to
    ``ext`` — the ``_vol`` proxy, or measured step times
    (``_measured_cost_fn``, ``plan_sweep``'s default), which only runs
    its timings when the grid actually has shapes to merge.  Returns
    ``[{"ids": [point indices], "ext": {...}}]`` ordered by first point
    id, ids ascending within each bucket.
    """
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    by_key: dict[tuple, list[int]] = {}
    for i, e in enumerate(extents):
        by_key.setdefault(tuple(e[k] for k in _SHAPE_KEYS), []).append(i)
    buckets = [{"ids": ids, "ext": dict(zip(_SHAPE_KEYS, key))}
               for key, ids in by_key.items()]
    if len(buckets) > 1:                   # uniform grids never pay cost_fn
        ideal = sum(cost_fn(e) for e in extents)

        def cost(b):
            return len(b["ids"]) * cost_fn(b["ext"])

        total = sum(cost(b) for b in buckets)
        while len(buckets) > 1:
            best = None
            for x in range(len(buckets)):
                for y in range(x + 1, len(buckets)):
                    ext = {k: max(buckets[x]["ext"][k], buckets[y]["ext"][k])
                           for k in _SHAPE_KEYS}
                    delta = ((len(buckets[x]["ids"])
                              + len(buckets[y]["ids"])) * cost_fn(ext)
                             - cost(buckets[x]) - cost(buckets[y]))
                    if best is None or delta < best[0]:
                        best = (delta, x, y, ext)
            delta, x, y, ext = best
            if (len(buckets) > max_buckets
                    or total + delta <= bucket_waste * ideal):
                merged = {"ids": buckets[x]["ids"] + buckets[y]["ids"],
                          "ext": ext}
                buckets = [b for i, b in enumerate(buckets)
                           if i not in (x, y)] + [merged]
                total += delta
            else:
                break
    for b in buckets:
        b["ids"].sort()
    buckets.sort(key=lambda b: b["ids"][0])
    return buckets


def _stack_points(inputs: list[EngineInputs], data_plane: dict,
                  seed_ids: list[int], seed_shared: bool) -> EngineInputs:
    """Stack one bucket's per-point inputs along a leading point axis.

    Data-plane fields take the plan-wide seed-major stack (same device
    buffers in every bucket); ``seed_idx`` becomes the per-point ``[Pb]``
    gather index (or stays the scalar 0 on single-seed plans, matching
    ``split_inputs``' ``shared_seed_idx`` side — keeping it unmapped keeps
    the engine's test/init gathers unbatched, so vmap never materializes
    P identical test-set copies); everything else stacks point-major.
    """
    def one(name):
        if name == "seed_idx":
            return jnp.int32(0) if seed_shared \
                else jnp.asarray(seed_ids, jnp.int32)
        if name in SHARED_DATA_FIELDS:
            return data_plane[name]
        vals = [getattr(i, name) for i in inputs]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *vals)

    return EngineInputs(**{f.name: one(f.name)
                           for f in dataclasses.fields(EngineInputs)})


@dataclasses.dataclass
class SweepBucket:
    """One shape bucket: a compiled-call-ready stack of compatible points."""
    point_ids: list            # indices into the plan's point order
    inputs: Optional[EngineInputs]  # stacked [Pb, ...], padded to bucket
    #   maxima.  None after a donated execute consumed this bucket (the
    #   donation contract: the stacked planes are handed to the compiled
    #   call and the plan stops pinning them).
    grid_max: dict             # this bucket's {"t","k","n","j","steps"}


@dataclasses.dataclass
class SweepPlan:
    """A bucketed, compiled-call-ready sweep: stacked inputs + metadata.

    Holds only host scalars per point besides the bucket inputs — the
    planning simulators (and their schedules/chains) are released once
    their latency/block summaries are extracted, so plan lifetime does not
    pin P sets of host state.  All buckets alias ONE seed-major data plane
    (``n_seeds`` rows), so plan memory scales with distinct seeds.
    """
    points: list                    # (overrides dict, seed) per grid point
    buckets: list                   # [SweepBucket], first-point order
    grid_max: dict                  # global {"t","k","n","j","steps"} maxima
    aggregator: str
    normalize: bool
    history_dtype: Any
    kernel_mode: str                # resolved kernel-plane backend (never
    #   "auto": plan_sweep resolves so runner caches key on the concrete
    #   mode — see repro.kernels.dispatch)
    n_seeds: int                    # distinct seeds in the data plane
    sim_latency: np.ndarray         # [P] paper latency model totals
    blocks: np.ndarray              # [P] committed blocks per point
    t_valid: np.ndarray             # [P] real rounds per point
    point_volume: np.ndarray        # [P] no-padding compute proxy per point

    @property
    def inputs(self) -> EngineInputs:
        """The single bucket's stacked inputs (single-bucket plans only —
        the PR 2 shape; multi-bucket plans use ``plan.buckets[i].inputs``)."""
        if len(self.buckets) != 1:
            raise ValueError(
                f"plan has {len(self.buckets)} shape buckets; per-bucket "
                "inputs live at plan.buckets[i].inputs")
        if self.buckets[0].inputs is None:
            raise ValueError(
                "this SweepPlan's bucket inputs were consumed by a donated "
                "execute_plan/run_plan; build a fresh plan, or run with "
                "donate=False to keep a plan re-runnable")
        return self.buckets[0].inputs

    def padding_stats(self) -> dict:
        """Padded-compute accounting for the chosen bucket plan.

        ``padded_flop_frac`` is the fraction of the plan's compute volume
        that is padding (0 = no waste); ``single_bucket_flop_frac`` is the
        same quantity had every point been padded to the global maxima
        (the PR 2 baseline this planner retires).
        """
        ideal = int(self.point_volume.sum())
        padded = sum(len(b.point_ids) * _vol(b.grid_max)
                     for b in self.buckets)
        single = len(self.points) * _vol(self.grid_max)
        return {
            "ideal_volume": ideal,
            "padded_volume": padded,
            "single_bucket_volume": single,
            "padded_flop_frac": 1.0 - ideal / padded,
            "single_bucket_flop_frac": 1.0 - ideal / single,
            "buckets": [dict(points=len(b.point_ids), **b.grid_max)
                        for b in self.buckets],
        }

    def describe(self) -> str:
        """Human-readable bucket plan (what the planner chose and why it's
        cheap) — logged by examples/sweep_topology.py and fig3_sweeps."""
        st = self.padding_stats()
        lines = [
            f"sweep plan: {len(self.points)} points -> "
            f"{len(self.buckets)} shape bucket(s), {self.n_seeds} distinct "
            f"seed(s) in the data plane; padded-compute waste "
            f"{st['padded_flop_frac']:.1%} (single-bucket baseline "
            f"{st['single_bucket_flop_frac']:.1%})"]
        for i, b in enumerate(self.buckets):
            g = b.grid_max
            lines.append(
                f"  bucket {i}: {len(b.point_ids)} point(s) padded to "
                f"T={g['t']} K={g['k']} N={g['n']} J={g['j']} "
                f"steps={g['steps']}")
        return "\n".join(lines)


@dataclasses.dataclass
class SweepResult:
    """Batched trajectories for a grid of runs (leading axis = grid point).

    Rows are padded to the grid's max round count: row ``p`` is valid up to
    ``t_valid[p]`` rounds; past that, ``accuracy`` repeats the final valid
    value, ``loss``/``grad_norm`` are 0, and ``sim_clock``/``sim_energy``
    repeat the final valid value.  ``trajectory(p)`` /
    ``latency_trajectory(p)`` / ``energy_trajectory(p)`` slice one point's
    valid prefix.  Rows are in original point order no matter how the
    planner bucketed them.
    """
    points: list              # (overrides dict, seed) per grid point
    accuracy: np.ndarray      # [P, T_max]
    loss: np.ndarray          # [P, T_max]
    grad_norm: np.ndarray     # [P, T_max]
    sim_clock: np.ndarray     # [P, T_max] cumulative simulated seconds
    sim_energy: np.ndarray    # [P, T_max] cumulative consensus energy (J)
    sim_latency: np.ndarray   # [P] paper's Sec. 5.1.4 expectation totals
    blocks: np.ndarray        # [P]
    t_valid: np.ndarray       # [P] real rounds per point

    def trajectory(self, p: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        tv = int(self.t_valid[p])
        return (self.accuracy[p, :tv], self.loss[p, :tv],
                self.grad_norm[p, :tv])

    def latency_trajectory(self, p: int) -> tuple[np.ndarray, np.ndarray]:
        """(simulated clock [tv], accuracy [tv]) — one point's
        time-to-accuracy curve (the latency fabric's x-axis)."""
        tv = int(self.t_valid[p])
        return self.sim_clock[p, :tv], self.accuracy[p, :tv]

    def energy_trajectory(self, p: int) -> tuple[np.ndarray, np.ndarray]:
        """(simulated clock [tv], cumulative consensus energy [tv] J) —
        one point's energy-over-time curve (the zoo's second cost axis)."""
        tv = int(self.t_valid[p])
        return self.sim_clock[p, :tv], self.sim_energy[p, :tv]

    def time_to_accuracy(self, p: int, target: float) -> float:
        """Simulated seconds until point ``p`` first reaches ``target``
        test accuracy; +inf when it never does."""
        clock, acc = self.latency_trajectory(p)
        hit = np.flatnonzero(acc >= target)
        return float(clock[hit[0]]) if hit.size else float("inf")

    def k_star_empirical(self, target: float
                         ) -> tuple[Optional[int], np.ndarray]:
        """The *measured* K* selector: the grid point reaching ``target``
        accuracy in the least simulated time.

        Returns ``(best_point_index, times[P])``; the index is None when
        no point reaches the target.  Reported next to the theoretical
        ``omega_bound`` K* (``repro.core.optimize_k``) by
        ``examples/latency_optimization.py`` / ``benchmarks/fig7_latency``
        — the empirical selector sees what the bound cannot: actual
        convergence speed and the actual consensus stalls of small-K
        windows.
        """
        times = np.array([self.time_to_accuracy(p, target)
                          for p in range(len(self.points))])
        if not np.isfinite(times).any():
            return None, times
        return int(np.argmin(times)), times


def plan_sweep(setting: BHFLSetting, seeds=(0,), *,
               overrides: Optional[list] = None,
               aggregator: str = "hieavg",
               device_stragglers: str = "temporary",
               edge_stragglers: str = "temporary",
               normalize: bool = False, history_dtype=None,
               kernel_mode: str = "auto",
               max_buckets: int = 4, bucket_waste: float = 1.25,
               bucket_cost: str = "measured",
               **sim_kw) -> SweepPlan:
    """Precompute a grid (overrides x seeds) into bucketed ``EngineInputs``.

    ``overrides`` entries may change topology and round counts
    (``PADDED_FIELDS``) — points are grouped into at most ``max_buckets``
    shape buckets by the padding-waste heuristic (``bucket_waste`` caps the
    total padded-compute ratio voluntary merges may reach; see
    ``_bucket_points``), and every point is padded to its bucket's maxima.
    ``bucket_cost`` prices a padded point for those decisions:
    ``"measured"`` (default) times one real train step per candidate
    device count through the plan's kernel path (compiled once, cached
    process-wide, strictly monotone in device count so noise can't flip
    the plan); ``"proxy"`` keeps the analytic ``t·k·n·j·steps`` volume.
    ``max_buckets=1`` forces the single global-max bucket (the PR 2
    behavior).  ``j_per_edge`` additionally accepts a per-edge list
    (Fig. 4b inconsistent-J deployments).  Geometry fields
    (``UNSUPPORTED_FIELDS``) raise immediately with the field named.

    Datasets/init weights are seed-deduped: one ``[n_seeds]`` stack shared
    by every bucket, with per-point ``seed_idx`` gathers inside the engine.

    ``kernel_mode`` is the kernel-plane backend knob (like
    ``history_dtype``): resolved here (``"auto"`` → fused Pallas kernels
    on TPU/GPU, pure-XLA reference on CPU) and baked into the plan so the
    cached runners key on the concrete mode.

    Each override may name its own ``"aggregation"``; see ``run_sweep``.
    The plan's aggregator is the grid's single value, or ``"switched"``
    when mixed (mixing a non-``SWITCHABLE_AGGREGATORS`` strategy raises).
    """
    from repro.fl.simulator import BHFLSimulator  # lazy: avoid import cycle

    kernel_mode = resolve_kernel_mode(kernel_mode)   # validate up front
    overrides = [dict(ov) for ov in (overrides or [{}])]
    _validate_overrides(overrides)
    # an override's explicit "seed" wins over the ``seeds`` cross product
    # and is NOT crossed with it (the simulator's seed argument governs
    # data/schedules/chain, so crossing would emit duplicate points)
    points = []
    for ov in overrides:
        if "seed" in ov:
            points.append((ov, int(ov["seed"])))
        else:
            points.extend((ov, seed) for seed in seeds)

    sims = []
    point_aggs = []
    for ov, seed in points:
        ov = dict(ov)
        ov.pop("seed", None)
        agg = ov.pop(AGGREGATION_FIELD, aggregator)
        point_aggs.append(agg)
        kw = dict(sim_kw)
        jpe = ov.pop("j_per_edge", None)
        if isinstance(jpe, (list, tuple, np.ndarray)):
            kw["j_per_edge"] = [int(j) for j in jpe]
        elif jpe is not None:
            ov["j_per_edge"] = int(jpe)
        sims.append(BHFLSimulator(
            dataclasses.replace(setting, **ov), agg,
            device_stragglers, edge_stragglers, normalize=normalize,
            seed=seed, **kw))

    # A mixed-aggregation grid compiles as the engine's traced "switched"
    # aggregator: every point's program computes hieavg/delayed_grad/fedavg
    # and tri-selects by its batched ``agg_sel`` scalar, so the whole grid
    # stays one padded shard_map call.  Single-aggregator grids keep the
    # cheaper static dispatch.
    distinct = sorted(set(point_aggs))
    if len(distinct) == 1:
        plan_aggregator = distinct[0]
    else:
        bad = [a for a in distinct if a not in SWITCHABLE_AGGREGATORS]
        if bad:
            raise ValueError(
                f"mixed-aggregation sweep includes {bad}, which cannot be "
                f"traced-switched; switchable: {SWITCHABLE_AGGREGATORS}. "
                "Run those aggregators as separate sweeps.")
        plan_aggregator = "switched"

    extents = [{"t": s.s.t_global_rounds, "k": s.s.k_edge_rounds,
                "n": s.N, "j": max(s.j_per_edge), "steps": s.steps}
               for s in sims]
    grid_max = {k: max(e[k] for e in extents) for k in _SHAPE_KEYS}
    if bucket_cost not in ("measured", "proxy"):
        raise ValueError(f"unknown bucket_cost {bucket_cost!r}; "
                         "expected 'measured' or 'proxy'")
    if bucket_cost == "measured":
        s0 = sims[0].s
        cost_fn = _measured_cost_fn((s0.image_hw, s0.batch_size, s0.cnn_c1,
                                     s0.cnn_c2, s0.n_classes, kernel_mode))
    else:
        cost_fn = _vol
    groups = _bucket_points(extents, max_buckets, bucket_waste, cost_fn)

    # seed-dedup: data/init arrays are a pure function of (seed, geometry),
    # and geometry is grid-constant — the first point of each distinct seed
    # becomes that seed's data-plane row (its device buffers are reused by
    # every same-seed point via share_data_from, so H2D puts scale with
    # distinct seeds), and the rows concatenate into ONE [n_seeds] stack
    # every bucket aliases.
    seed_to_idx: dict = {}
    for s in sims:
        seed_to_idx.setdefault(s.seed, len(seed_to_idx))
    first_by_seed: dict = {}
    built: list = []          # (group, [EngineInputs per point])
    for g in groups:
        ext = g["ext"]
        binputs = []
        for i in g["ids"]:
            s = sims[i]
            inp = build_inputs(
                s, t_max=ext["t"], k_max=ext["k"], n_max=ext["n"],
                j_max=ext["j"], steps_max=ext["steps"],
                share_data_from=first_by_seed.get(s.seed))
            first_by_seed.setdefault(s.seed, inp)
            binputs.append(inp)
        shapes = [jax.tree.map(jnp.shape, i) for i in binputs]
        if any(sh != shapes[0] for sh in shapes[1:]):
            raise ValueError(
                "sweep grid points disagree on array shapes even after "
                "padding — the base setting/sim kwargs (image size, batch "
                "size, data sizes) must be identical across the grid")
        built.append((g, binputs))

    reps = [first_by_seed[seed] for seed in seed_to_idx]
    data_plane = {}
    for name in SHARED_DATA_FIELDS:
        vals = [getattr(r, name) for r in reps]
        data_plane[name] = vals[0] if len(vals) == 1 else jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *vals)

    seed_shared = len(seed_to_idx) == 1
    buckets = [SweepBucket(
        point_ids=list(g["ids"]),
        inputs=_stack_points(binputs, data_plane,
                             [seed_to_idx[sims[i].seed] for i in g["ids"]],
                             seed_shared),
        grid_max=dict(g["ext"]))
        for g, binputs in built]
    return SweepPlan(points=points, buckets=buckets, grid_max=grid_max,
                     aggregator=plan_aggregator, normalize=normalize,
                     history_dtype=history_dtype,
                     kernel_mode=kernel_mode,
                     n_seeds=len(seed_to_idx),
                     sim_latency=np.asarray([s.paper_latency()
                                             for s in sims]),
                     blocks=np.asarray([len(s.chain.blocks) - 1
                                        for s in sims]),
                     t_valid=np.asarray([s.s.t_global_rounds
                                         for s in sims]),
                     point_volume=np.asarray([_vol(e) for e in extents]))


# ---------------------------------------------------------------- placement
def _engine_runner(aggregator: str, normalize: bool, history_dtype,
                   kernel_mode: str):
    """The per-point engine call over split ``(hot, shared)`` input dicts
    (``engine.split_inputs``): the hot dict rides the stacked point axis
    (vmap ``in_axes=0`` / shard_map point spec) and is the donation
    target; the shared dict is the seed-major data plane (unmapped /
    replicated, never donated)."""
    def runner(hot, shared):
        return run_engine(merge_inputs(hot, shared), aggregator=aggregator,
                          normalize=normalize, history_dtype=history_dtype,
                          kernel_mode=kernel_mode)

    return runner


@functools.lru_cache(maxsize=None)
def _vmap_runner(aggregator: str, normalize: bool, history_dtype,
                 kernel_mode: str, donate: bool):
    """jit(vmap(run_engine)) over the stacked point axis — cached like
    ``_sharded_runner``.  ``donate=True`` hands the hot (stacked) input
    dict to XLA for buffer reuse: a big bucketed grid does not hold the
    caller's copy of the stacked planes alive next to the running
    program's working set.  The shared data plane is never donated."""
    fn = jax.vmap(_engine_runner(aggregator, normalize, history_dtype,
                                 kernel_mode), in_axes=(0, None))
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=None)
def _sharded_runner(aggregator: str, normalize: bool, history_dtype,
                    mesh, spec, kernel_mode: str, donate: bool):
    """jit(shard_map(vmap(run_engine))) — cached so repeated sweeps with
    the same static config reuse the compiled executable instead of paying
    a fresh trace + compile per call (jit caches by callable identity; a
    multi-bucket plan compiles one program per bucket *shape* under the
    same cached callable).  ``spec`` shards every hot (stacked) leaf over
    the mesh point axis; the shared data plane is replicated
    (``sweep_data_spec``).  ``donate`` as in ``_vmap_runner``."""
    from jax.experimental.shard_map import shard_map

    inner = jax.vmap(_engine_runner(aggregator, normalize, history_dtype,
                                    kernel_mode), in_axes=(0, None))
    # shard_map has no replication rule for pallas_call, so the
    # fused-kernel modes cannot lower with the checker on; keep it for
    # the pure-XLA mode, where it still guards the replicated data plane
    sharded = shard_map(inner, mesh=mesh,
                        in_specs=(spec, sweep_data_spec()),
                        out_specs=spec, check_rep=(kernel_mode == "xla"))
    return jax.jit(sharded, donate_argnums=(0,) if donate else ())


def execute_plan(plan: SweepPlan, *, mesh=None, placement: str = "auto",
                 donate: bool = True
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                            np.ndarray]:
    """Run a plan's buckets — one compiled call each — and merge outputs.

    Returns per-point ``(accuracy, loss, grad_norm, sim_clock,
    sim_energy)``, each ``[P, T_max]`` with ``T_max = plan.grid_max["t"]``,
    in original point order.  Rows from a bucket padded to fewer rounds
    are extended by the engine's own tail convention (accuracy/clock/
    energy repeat the final value, loss/grad are 0), so bucketing is
    invisible to every accessor.

    ``placement``: ``"auto"`` shards each bucket's point axis over the mesh
    ``data`` axis when ``sweep_spec`` says it divides (falling back to
    single-device ``vmap`` per bucket — the same autoscaling contract as
    the weight shardings); ``"vmap"`` forces the single-device path;
    ``"shard"`` requires the sharded path for every bucket and raises if
    the mesh cannot take one.

    ``donate`` (default True): each bucket's stacked hot input planes are
    donated to its compiled call, so a big grid never holds the plan's
    copy of the stacked state next to the run's working set.  The shared
    seed-major data plane is never donated (all buckets alias it).  After
    a donated execute the plan's bucket inputs are CONSUMED — re-running
    the same ``SweepPlan`` object requires ``donate=False`` (or a fresh
    plan; ``run_sweep`` re-plans per call either way).
    """
    if placement not in ("auto", "vmap", "shard"):
        raise ValueError(f"unknown placement {placement!r}")
    if placement != "vmap" and mesh is None:
        mesh = make_sweep_mesh()

    # resolve every bucket's spec up front so placement='shard' fails fast
    # (before any bucket compiles/runs) rather than mid-plan
    specs = [sweep_spec(len(b.point_ids), mesh) if placement != "vmap"
             else PartitionSpec() for b in plan.buckets]
    if placement == "shard":
        for b, spec in zip(plan.buckets, specs):
            if spec == PartitionSpec():
                raise ValueError(
                    f"placement='shard' but a bucket of {len(b.point_ids)} "
                    f"grid points (of {len(plan.points)} total) does not "
                    f"divide a >1 mesh axis (mesh="
                    f"{dict(mesh.shape) if mesh is not None else None}); "
                    "force max_buckets=1 or use placement='auto'")

    P_, Tg = len(plan.points), plan.grid_max["t"]
    acc = np.zeros((P_, Tg), np.float32)
    loss = np.zeros((P_, Tg), np.float32)
    gn = np.zeros((P_, Tg), np.float32)
    clock = np.zeros((P_, Tg), np.float32)
    energy = np.zeros((P_, Tg), np.float32)
    seed_shared = plan.n_seeds == 1
    for b, spec in zip(plan.buckets, specs):
        if b.inputs is None:
            raise ValueError(
                "this SweepPlan's bucket inputs were consumed by a "
                "previous donated execute_plan/run_plan; build a fresh "
                "plan, or run with donate=False to keep a plan re-runnable")
        hot, shared = split_inputs(b.inputs, shared_seed_idx=seed_shared)
        with warnings.catch_warnings():
            # expected under donation: the engine's [P, T] outputs are far
            # smaller than the stacked input planes, so XLA rarely finds
            # an input-output alias — the reference release below is the
            # real win
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            if spec == PartitionSpec():
                outs = _vmap_runner(plan.aggregator, plan.normalize,
                                    plan.history_dtype, plan.kernel_mode,
                                    donate)(hot, shared)
            else:
                outs = _sharded_runner(plan.aggregator, plan.normalize,
                                       plan.history_dtype, mesh, spec,
                                       plan.kernel_mode, donate)(hot, shared)
        if donate:
            # the compiled call has consumed the stacked planes: drop the
            # plan's reference so it stops pinning the caller-side copy
            # (the shared data plane stays — every bucket and same-seed
            # point aliases it).  Only after a SUCCESSFUL dispatch: a
            # bucket that failed to compile/run stays intact, so the plan
            # remains retryable
            b.inputs = None
        del hot
        a, l, g, c, en = (np.asarray(o) for o in outs)
        ids = np.asarray(b.point_ids)
        Tb = a.shape[1]
        acc[ids, :Tb] = a
        acc[ids, Tb:] = a[:, -1:]
        loss[ids, :Tb] = l
        gn[ids, :Tb] = g
        clock[ids, :Tb] = c
        clock[ids, Tb:] = c[:, -1:]
        energy[ids, :Tb] = en
        energy[ids, Tb:] = en[:, -1:]
    return acc, loss, gn, clock, energy


def run_plan(plan: SweepPlan, *, mesh=None, placement: str = "auto",
             donate: bool = True) -> SweepResult:
    """Execute a prepared plan and package a ``SweepResult`` — lets callers
    inspect/log the bucket plan (``plan.describe()``) before running it.
    ``donate`` as in ``execute_plan`` (donated bucket inputs are consumed
    — pass False to keep the plan re-runnable)."""
    accs, losses, deltas, clocks, energies = execute_plan(
        plan, mesh=mesh, placement=placement, donate=donate)
    return SweepResult(
        points=plan.points,
        accuracy=accs, loss=losses, grad_norm=deltas, sim_clock=clocks,
        sim_energy=energies,
        sim_latency=plan.sim_latency, blocks=plan.blocks,
        t_valid=plan.t_valid)


# ------------------------------------------------------------------ wrapper
def run_sweep(setting: BHFLSetting, seeds=(0,), *,
              overrides: Optional[list] = None,
              aggregator: str = "hieavg",
              device_stragglers: str = "temporary",
              edge_stragglers: str = "temporary",
              normalize: bool = False, history_dtype=None,
              kernel_mode: str = "auto",
              mesh=None, placement: str = "auto",
              max_buckets: int = 4, bucket_waste: float = 1.25,
              bucket_cost: str = "measured",
              **sim_kw) -> SweepResult:
    """Grids (including topology/round grids) as a few compiled sharded
    calls — one per shape bucket.

    ``overrides`` is a list of ``BHFLSetting`` field-override dicts crossed
    with ``seeds``.  Straggler fractions/kinds, gamma/lambda, cold-boot
    length, lr schedule, and seeds vary as pure data; ``n_edges``,
    ``j_per_edge`` (int or per-edge list), ``k_edge_rounds``, and
    ``t_global_rounds`` vary via padding to the bucket max (``max_buckets``
    / ``bucket_waste`` steer the padding-waste heuristic, priced by
    measured step times unless ``bucket_cost="proxy"``; ``max_buckets=1``
    restores the single global-max call); model/data geometry fields raise
    a ``ValueError`` naming the field.  Multi-seed grids keep one dataset
    copy per *distinct seed* in device memory, not per point.

    An override may also carry the ``"aggregation"`` pseudo-field (not a
    ``BHFLSetting`` field): the per-point aggregation strategy.  A grid
    mixing ``SWITCHABLE_AGGREGATORS`` compiles ONE traced-``"switched"``
    program selected per point by a batched scalar — e.g. HieAvg vs
    delayed-gradient in a single padded shard_map call.
    """
    plan = plan_sweep(setting, seeds, overrides=overrides,
                      aggregator=aggregator,
                      device_stragglers=device_stragglers,
                      edge_stragglers=edge_stragglers, normalize=normalize,
                      history_dtype=history_dtype, kernel_mode=kernel_mode,
                      max_buckets=max_buckets,
                      bucket_waste=bucket_waste, bucket_cost=bucket_cost,
                      **sim_kw)
    return run_plan(plan, mesh=mesh, placement=placement)
