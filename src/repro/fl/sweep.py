"""Sweep fabric — shape-polymorphic sweep planner, sharded over the mesh.

The paper's headline claims are *grids*: convergence vs. straggler fraction
(Fig. 3), non-IID skew (Fig. 4), topology (N edges x J devices x K edge
rounds), consensus latency.  PR 1's ``run_sweep`` could only vmap grids
whose points agreed on every array shape; anything touching topology or
round counts fell back to one compiled engine run per point.

This module turns sweeps into a proper three-layer subsystem:

  Planner   ``plan_sweep`` classifies override fields (batchable / paddable
            / unsupported-with-a-clear-error), builds every grid point's
            ``EngineInputs`` padded to the grid maxima (T/K/N/J/steps), and
            stacks them along a leading point axis.  Padded extents are
            numeric no-ops inside ``run_engine``: padded edges/devices
            carry zero aggregation weight, padded rounds pass the scan
            carry through, padded SGD steps apply no update.  Each point's
            real extents ride along as ``t_valid``/``k_valid``/``n_valid``/
            ``s_valid`` scalars.

  Placement ``execute_plan`` shards the stacked point axis across the mesh
            ``data`` axis with ``shard_map`` (``launch.sharding.SWEEP_RULES``
            via ``sweep_spec``) and vmaps within each shard.  The same
            autoscaling contract as the weight shardings applies: if the
            point count does not divide a >1 mesh axis, the whole grid runs
            as a single-device ``vmap`` instead of failing to lower.

  Callers   ``run_sweep`` is the ``BHFLSimulator``-facing wrapper:
            plan -> execute -> package a ``SweepResult``.  It is what
            benchmarks/fig3_sweeps.py, fig4_heterogeneity.py, and the
            examples drive; tests/test_sweep_fabric.py pins every padded,
            sharded point to a standalone ``run_engine`` run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.configs.bhfl_cnn import BHFLSetting
from repro.fl.engine import EngineInputs, build_inputs, run_engine
from repro.launch.mesh import make_sweep_mesh
from repro.launch.sharding import sweep_spec

# ------------------------------------------------------- field classification
#: Fields a grid may vary freely: they only change *data* (schedules, decay
#: scalars, batch indices, per-round latency draws), never array shapes.
#: The latency-fabric fields (lm_device/lp_device/lm_edge/link_latency/
#: consensus_mult) batch because ``build_inputs`` bakes them into the
#: ``dev_time``/``cons_time``/``edge_hop`` planes of ``EngineInputs`` —
#: a consensus-latency x topology x K grid is ONE compiled call.
BATCHED_FIELDS = frozenset({
    "straggler_frac", "gamma0", "lam", "t_cold_boot", "classes_per_device",
    "lr0", "lr_decay", "permanent_stop_round", "seed",
    "lm_device", "lp_device", "lm_edge", "link_latency", "consensus_mult",
})

#: Fields that change array shapes but that the planner absorbs by padding
#: every point to the grid maximum.
PADDED_FIELDS = frozenset({
    "n_edges", "j_per_edge", "k_edge_rounds", "t_global_rounds",
})

#: Shape-defining fields padding cannot absorb (they change the model or
#: data geometry itself) — swept values get a clear error naming the field.
UNSUPPORTED_FIELDS = frozenset({
    "image_hw", "cnn_c1", "cnn_c2", "n_classes", "batch_size",
})


def _validate_overrides(overrides: list[dict]) -> None:
    setting_fields = {f.name for f in dataclasses.fields(BHFLSetting)}
    for ov in overrides:
        for name in ov:
            if name not in setting_fields:
                raise ValueError(
                    f"run_sweep: {name!r} is not a BHFLSetting field "
                    f"(known fields: {sorted(setting_fields)})")
            if name in UNSUPPORTED_FIELDS:
                raise ValueError(
                    f"run_sweep cannot sweep {name!r}: it changes the "
                    "model/data geometry, which padding cannot absorb. "
                    "Fix it across the grid (pass it via the base setting) "
                    "or run separate sweeps per value. Sweepable shape "
                    f"fields: {sorted(PADDED_FIELDS)}; data fields: "
                    f"{sorted(BATCHED_FIELDS)}.")
            # remaining fields are BATCHED or PADDED — both fine.


# ------------------------------------------------------------------ planner
#: ``EngineInputs`` fields that depend only on the seed and the
#: (grid-constant) data/model geometry — byte-identical across same-seed
#: points, so the planner keeps ONE copy and replicates it at placement
#: time instead of stacking P copies on device (the training set dominates
#: input bytes at real grid sizes).
SHARED_DATA_FIELDS = frozenset({"train_x", "train_y", "test_x", "test_y",
                                "init_w"})


def _per_field(data_shared: bool, on_shared, on_stacked) -> EngineInputs:
    """EngineInputs-shaped pytree prefix: one marker per field (used for
    ``vmap`` in_axes and ``shard_map`` in_specs)."""
    return EngineInputs(**{
        f.name: (on_shared if data_shared and f.name in SHARED_DATA_FIELDS
                 else on_stacked)
        for f in dataclasses.fields(EngineInputs)})


def _stack_points(inputs: list[EngineInputs],
                  data_shared: bool) -> EngineInputs:
    def one(name):
        vals = [getattr(i, name) for i in inputs]
        if data_shared and name in SHARED_DATA_FIELDS:
            return vals[0]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *vals)

    return EngineInputs(**{f.name: one(f.name)
                           for f in dataclasses.fields(EngineInputs)})


@dataclasses.dataclass
class SweepPlan:
    """A compiled-call-ready sweep: stacked padded inputs + metadata.

    Holds only host scalars per point besides ``inputs`` — the planning
    simulators (and their schedules/chains) are released once their
    latency/block summaries are extracted, so plan lifetime does not pin
    P sets of host state.
    """
    points: list                    # (overrides dict, seed) per grid point
    inputs: EngineInputs            # stacked [P, ...], padded to grid maxima
    grid_max: dict                  # {"t":..,"k":..,"n":..,"j":..,"steps":..}
    aggregator: str
    normalize: bool
    history_dtype: Any
    data_shared: bool               # train/test/init kept as ONE copy
    sim_latency: np.ndarray         # [P] paper latency model totals
    blocks: np.ndarray              # [P] committed blocks per point
    t_valid: np.ndarray             # [P] real rounds per point


@dataclasses.dataclass
class SweepResult:
    """Batched trajectories for a grid of runs (leading axis = grid point).

    Rows are padded to the grid's max round count: row ``p`` is valid up to
    ``t_valid[p]`` rounds; past that, ``accuracy`` repeats the final valid
    value, ``loss``/``grad_norm`` are 0, and ``sim_clock`` repeats the
    final valid clock.  ``trajectory(p)`` / ``latency_trajectory(p)`` slice
    one point's valid prefix.
    """
    points: list              # (overrides dict, seed) per grid point
    accuracy: np.ndarray      # [P, T_max]
    loss: np.ndarray          # [P, T_max]
    grad_norm: np.ndarray     # [P, T_max]
    sim_clock: np.ndarray     # [P, T_max] cumulative simulated seconds
    sim_latency: np.ndarray   # [P] paper's Sec. 5.1.4 expectation totals
    blocks: np.ndarray        # [P]
    t_valid: np.ndarray       # [P] real rounds per point

    def trajectory(self, p: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        tv = int(self.t_valid[p])
        return (self.accuracy[p, :tv], self.loss[p, :tv],
                self.grad_norm[p, :tv])

    def latency_trajectory(self, p: int) -> tuple[np.ndarray, np.ndarray]:
        """(simulated clock [tv], accuracy [tv]) — one point's
        time-to-accuracy curve (the latency fabric's x-axis)."""
        tv = int(self.t_valid[p])
        return self.sim_clock[p, :tv], self.accuracy[p, :tv]

    def time_to_accuracy(self, p: int, target: float) -> float:
        """Simulated seconds until point ``p`` first reaches ``target``
        test accuracy; +inf when it never does."""
        clock, acc = self.latency_trajectory(p)
        hit = np.flatnonzero(acc >= target)
        return float(clock[hit[0]]) if hit.size else float("inf")

    def k_star_empirical(self, target: float
                         ) -> tuple[Optional[int], np.ndarray]:
        """The *measured* K* selector: the grid point reaching ``target``
        accuracy in the least simulated time.

        Returns ``(best_point_index, times[P])``; the index is None when
        no point reaches the target.  Reported next to the theoretical
        ``omega_bound`` K* (``repro.core.optimize_k``) by
        ``examples/latency_optimization.py`` / ``benchmarks/fig7_latency``
        — the empirical selector sees what the bound cannot: actual
        convergence speed and the actual consensus stalls of small-K
        windows.
        """
        times = np.array([self.time_to_accuracy(p, target)
                          for p in range(len(self.points))])
        if not np.isfinite(times).any():
            return None, times
        return int(np.argmin(times)), times


def plan_sweep(setting: BHFLSetting, seeds=(0,), *,
               overrides: Optional[list] = None,
               aggregator: str = "hieavg",
               device_stragglers: str = "temporary",
               edge_stragglers: str = "temporary",
               normalize: bool = False, history_dtype=None,
               **sim_kw) -> SweepPlan:
    """Precompute a grid (overrides x seeds) into one stacked ``EngineInputs``.

    ``overrides`` entries may change topology and round counts
    (``PADDED_FIELDS``) — every point is padded to the grid maxima so the
    stack is rectangular.  ``j_per_edge`` additionally accepts a per-edge
    list (Fig. 4b inconsistent-J deployments).  Geometry fields
    (``UNSUPPORTED_FIELDS``) raise immediately with the field named.
    """
    from repro.fl.simulator import BHFLSimulator  # lazy: avoid import cycle

    overrides = [dict(ov) for ov in (overrides or [{}])]
    _validate_overrides(overrides)
    # an override's explicit "seed" wins over the ``seeds`` cross product
    # and is NOT crossed with it (the simulator's seed argument governs
    # data/schedules/chain, so crossing would emit duplicate points)
    points = []
    for ov in overrides:
        if "seed" in ov:
            points.append((ov, int(ov["seed"])))
        else:
            points.extend((ov, seed) for seed in seeds)

    sims = []
    for ov, seed in points:
        ov = dict(ov)
        ov.pop("seed", None)
        kw = dict(sim_kw)
        jpe = ov.pop("j_per_edge", None)
        if isinstance(jpe, (list, tuple, np.ndarray)):
            kw["j_per_edge"] = [int(j) for j in jpe]
        elif jpe is not None:
            ov["j_per_edge"] = int(jpe)
        sims.append(BHFLSimulator(
            dataclasses.replace(setting, **ov), aggregator,
            device_stragglers, edge_stragglers, normalize=normalize,
            seed=seed, **kw))

    grid_max = {
        "t": max(s.s.t_global_rounds for s in sims),
        "k": max(s.s.k_edge_rounds for s in sims),
        "n": max(s.N for s in sims),
        "j": max(max(s.j_per_edge) for s in sims),
        "steps": max(s.steps for s in sims),
    }
    # dataset/init dedup: those arrays are a pure function of (seed,
    # geometry), and geometry is grid-constant — points with the same
    # seed reuse the first such point's device buffers, so H2D puts scale
    # with the number of distinct seeds, not grid points.  With exactly
    # one seed the stack itself is also elided (``data_shared``: one
    # unstacked copy, replicated at placement time).
    data_shared = len({s.seed for s in sims}) == 1
    first_by_seed: dict = {}
    inputs: list[EngineInputs] = []
    for s in sims:
        inp = build_inputs(
            s, t_max=grid_max["t"], k_max=grid_max["k"],
            n_max=grid_max["n"], j_max=grid_max["j"],
            steps_max=grid_max["steps"],
            share_data_from=first_by_seed.get(s.seed))
        first_by_seed.setdefault(s.seed, inp)
        inputs.append(inp)
    shapes = [jax.tree.map(jnp.shape, i) for i in inputs]
    if any(s != shapes[0] for s in shapes[1:]):
        raise ValueError(
            "sweep grid points disagree on array shapes even after padding "
            "— the base setting/sim kwargs (image size, batch size, data "
            "sizes) must be identical across the grid")
    stacked = _stack_points(inputs, data_shared)
    return SweepPlan(points=points, inputs=stacked, grid_max=grid_max,
                     aggregator=aggregator, normalize=normalize,
                     history_dtype=history_dtype, data_shared=data_shared,
                     sim_latency=np.asarray([s.paper_latency()
                                             for s in sims]),
                     blocks=np.asarray([len(s.chain.blocks) - 1
                                        for s in sims]),
                     t_valid=np.asarray([s.s.t_global_rounds
                                         for s in sims]))


# ---------------------------------------------------------------- placement
@functools.lru_cache(maxsize=None)
def _vmap_runner(aggregator: str, normalize: bool, history_dtype,
                 data_shared: bool):
    def runner(inp):
        return run_engine(inp, aggregator=aggregator, normalize=normalize,
                          history_dtype=history_dtype)

    return jax.vmap(runner, in_axes=(_per_field(data_shared, None, 0),))


@functools.lru_cache(maxsize=None)
def _sharded_runner(aggregator: str, normalize: bool, history_dtype,
                    mesh, spec, data_shared: bool):
    """jit(shard_map(vmap(run_engine))) — cached so repeated sweeps with
    the same static config reuse the compiled executable instead of paying
    a fresh trace + compile per call (jit caches by callable identity)."""
    from jax.experimental.shard_map import shard_map

    inner = _vmap_runner(aggregator, normalize, history_dtype, data_shared)
    sharded = shard_map(
        inner, mesh=mesh,
        in_specs=(_per_field(data_shared, PartitionSpec(), spec),),
        out_specs=spec)
    return jax.jit(sharded)


def execute_plan(plan: SweepPlan, *, mesh=None, placement: str = "auto"
                 ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                            jnp.ndarray]:
    """Run a plan's stacked grid as ONE compiled call.

    Returns stacked per-point ``(accuracy, loss, grad_norm, sim_clock)``,
    each ``[P, T_max]``.

    ``placement``: ``"auto"`` shards the point axis over the mesh ``data``
    axis when ``sweep_spec`` says it divides (falling back to single-device
    ``vmap`` otherwise — the same autoscaling contract as the weight
    shardings); ``"vmap"`` forces the single-device path; ``"shard"``
    requires the sharded path and raises if the mesh cannot take it.
    """
    if placement not in ("auto", "vmap", "shard"):
        raise ValueError(f"unknown placement {placement!r}")
    n_points = len(plan.points)

    spec = PartitionSpec()
    if placement != "vmap":
        mesh = mesh if mesh is not None else make_sweep_mesh()
        spec = sweep_spec(n_points, mesh)
    if spec == PartitionSpec():
        if placement == "shard":
            raise ValueError(
                f"placement='shard' but {n_points} grid points do not "
                f"divide a >1 mesh axis "
                f"(mesh={dict(mesh.shape) if mesh is not None else None})")
        return _vmap_runner(plan.aggregator, plan.normalize,
                            plan.history_dtype,
                            plan.data_shared)(plan.inputs)
    return _sharded_runner(plan.aggregator, plan.normalize,
                           plan.history_dtype, mesh, spec,
                           plan.data_shared)(plan.inputs)


# ------------------------------------------------------------------ wrapper
def run_sweep(setting: BHFLSetting, seeds=(0,), *,
              overrides: Optional[list] = None,
              aggregator: str = "hieavg",
              device_stragglers: str = "temporary",
              edge_stragglers: str = "temporary",
              normalize: bool = False, history_dtype=None,
              mesh=None, placement: str = "auto",
              **sim_kw) -> SweepResult:
    """Grids (including topology/round grids) as ONE compiled sharded call.

    ``overrides`` is a list of ``BHFLSetting`` field-override dicts crossed
    with ``seeds``.  Straggler fractions/kinds, gamma/lambda, cold-boot
    length, lr schedule, and seeds vary as pure data; ``n_edges``,
    ``j_per_edge`` (int or per-edge list), ``k_edge_rounds``, and
    ``t_global_rounds`` vary via padding to the grid max; model/data
    geometry fields raise a ``ValueError`` naming the field.
    """
    plan = plan_sweep(setting, seeds, overrides=overrides,
                      aggregator=aggregator,
                      device_stragglers=device_stragglers,
                      edge_stragglers=edge_stragglers, normalize=normalize,
                      history_dtype=history_dtype, **sim_kw)
    accs, losses, deltas, clocks = execute_plan(plan, mesh=mesh,
                                                placement=placement)
    return SweepResult(
        points=plan.points,
        accuracy=np.asarray(accs), loss=np.asarray(losses),
        grad_norm=np.asarray(deltas), sim_clock=np.asarray(clocks),
        sim_latency=plan.sim_latency, blocks=plan.blocks,
        t_valid=plan.t_valid)
