"""BHFL simulator — runs the paper's experiments (Sec. 6) end to end.

Simulates N edge servers × J_i local devices training the paper's CNN on a
non-IID class-partitioned dataset, with the full BHFL workflow:

  1. Updates Submission — every device trains locally (vmapped SGD epoch),
  2. Edge Aggregation   — HieAvg (or a benchmark aggregator) per edge,
     repeated K times per global round,
  3. Blockchain Consensus — Raft leader election overlapped with the K edge
     rounds (latency-accounted, Sec. 5.1.3),
  4. Global Aggregation — the leader aggregates edge models, commits a block.

Straggler schedules (permanent / temporary, per layer) drive boolean masks;
the aggregator sees only the masks, exactly like a real deadline-based
system.  Aggregators: ``hieavg`` (the paper), ``t_fedavg`` (drop),
``d_fedavg`` (reuse last), ``delayed_grad`` (stale updates arrive one round
late with staleness-discounted weights, arXiv:2102.06329), ``fedavg``
(oracle; meaningful with no-straggler schedules).

All devices are simulated in one jitted vmap over the stacked device
dimension, so a full Fig. 2 run takes seconds on CPU.

``run()`` delegates to the fully-jitted batched engine (``repro.fl.engine``):
one compiled program per run instead of a Python loop per edge per round.
The original per-edge loop is kept as ``run_legacy()`` — it is the numerics
reference for ``tests/test_engine_parity.py`` and the baseline for
``BENCH_engine.json``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bhfl_cnn import BHFLSetting
from repro.core import (baselines, consensus as _consensus, hieavg,
                        latency as lat, rng as rng_streams,
                        straggler as strag)
from repro.kernels import dispatch as _kdispatch
from repro.data import by_class, class_images, class_pools
from repro.models import cnn_accuracy, cnn_specs, init_from_specs
from repro.optim import paper_lr

from repro.checkpoint import ckpt as _ckpt

from . import engine as _engine
from . import faults as _faults
from . import population as _population

PyTree = Any

# the shared local-training epoch lives in the engine module now
_train_epoch = _engine.train_epoch


def _stack(trees: list[PyTree]) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _index(tree: PyTree, i) -> PyTree:
    return jax.tree.map(lambda x: x[i], tree)


def _bcast_like(tree: PyTree, n: int) -> PyTree:
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)


# ----------------------------------------------------------------- results
@dataclasses.dataclass
class RunResult:
    accuracy: np.ndarray          # [T] test accuracy after each global round
    loss: np.ndarray              # [T] mean local training loss
    grad_norm: np.ndarray         # [T] proxy: global-model round-to-round delta
    wall_time: float
    sim_latency: float            # paper's latency model total (Sec. 5.1.4)
    blocks: int                   # committed blockchain blocks
    chain_valid: bool
    sim_clock: Optional[np.ndarray] = None  # [T] cumulative simulated
    #   seconds after each global round (latency fabric; engine path —
    #   pairs with ``accuracy`` into a time-to-accuracy curve).
    #   ``run_legacy`` leaves it None.
    sim_energy: Optional[np.ndarray] = None  # [T] cumulative consensus
    #   energy (J) after each global round — the second traced cost axis
    #   (consensus zoo; engine path only, ``run_legacy`` leaves it None).


# --------------------------------------------------------------- simulator
class BHFLSimulator:
    """One BHFL deployment over the synthetic MNIST surrogate."""

    def __init__(self, setting: BHFLSetting = BHFLSetting(),
                 aggregator: str = "hieavg",
                 device_stragglers: str = "temporary",
                 edge_stragglers: str = "temporary",
                 j_per_edge: Optional[list[int]] = None,
                 n_train: int = 4000, n_test: int = 1000,
                 steps_per_epoch: Optional[int] = None,
                 normalize: bool = False,
                 fail_leader_at: Optional[int] = None,
                 seed: Optional[int] = None,
                 history_dtype=None,
                 kernel_mode: str = "auto",
                 population=None,
                 j_cohort: Optional[int] = None,
                 device_rates: Optional[list] = None,
                 faults: Optional[_faults.FaultSpec] = None):
        """``fail_leader_at``: global round at which the current Raft
        leader crashes — the paper's single-point-of-failure scenario.
        The consortium re-elects and training continues (the failed edge
        also becomes a permanent straggler at the global layer).  Since
        the chaos plane landed this is sugar for a one-event
        ``FaultSpec(leader_crash_round=...)`` — it rides the fault
        schedule, parity-pinned bitwise against the scripted path.

        ``faults``: an explicit ``repro.fl.faults.FaultSpec`` overriding
        the setting's fault fields (``edge_fail_rate`` …
        ``stall_backoff``), from which the per-round fault schedule is
        compiled by default.  The schedule draws from the deployment's
        dedicated ``"faults"`` RNG stream (an all-zero spec is
        draw-free) and is pure data: it feeds the chain replay (validator
        churn, quorum stall-and-retry) and the engine's submission/edge
        masks (outages, bursts, message loss).  Engine path only —
        ``run_legacy`` refuses stochastic fault processes.

        ``history_dtype``: HieAvg history storage dtype override (engine
        path only) — straggler estimation keeps two extra model copies
        per participant per layer; ``jnp.bfloat16`` cuts that 2× at no
        measured accuracy cost, ``jnp.float8_e4m3fn`` 4× with an accuracy
        penalty.  The estimation math stays f32.  See EXPERIMENTS.md X1.

        ``kernel_mode``: the kernel-plane backend knob (engine path only,
        like ``history_dtype``) — ``"auto"`` runs the fused Pallas
        aggregation/SGD kernels on TPU/GPU and the pure-XLA reference on
        CPU; ``"interpret"``/``"pallas"``/``"xla"`` force a path.  See
        ``repro.kernels.dispatch``.

        ``population`` (+ ``j_cohort``): population mode — an int device
        -population size (with ``j_cohort`` devices gathered per edge per
        round), a ``fl.population.PopulationSpec``, or a prebuilt
        ``DevicePopulation`` store (shared across sweep points).  Each
        global round samples a cohort ``[N, j_cohort]`` from the
        population by index; straggler propensity, data shard, and speed
        come from the occupant's profile while all per-round randomness
        is keyed by slot, so memory and per-round work scale with the
        cohort, not the population.  Engine path only (``run_legacy``
        refuses).  See ``repro.fl.population``.

        ``device_rates``: per-device clock-rate multipliers (length =
        total devices, positive) for a heterogeneous fleet — device d's
        per-round latency draw is scaled by ``device_rates[d]`` (before
        straggler slowdown / deadline capping) instead of iid draws
        around one shared ``LatencyParams``.  Refused in population
        mode, where the occupant's ``time_scale`` profile already plays
        this role per cohort."""
        self.s = setting
        self.aggregator = aggregator
        self.normalize = normalize
        self.history_dtype = history_dtype
        # resolve once: validates the knob early and keys the engine's jit
        # cache on the concrete mode instead of "auto"
        self.kernel_mode = _kdispatch.resolve_kernel_mode(kernel_mode)
        self.fail_leader_at = fail_leader_at
        self.seed = setting.seed if seed is None else seed
        self.N = setting.n_edges
        # ---- population mode: the cohort shape is fixed by the store
        if population is not None:
            if j_per_edge is not None:
                raise ValueError(
                    "population mode fixes the per-edge device count to "
                    "j_cohort; pass j_cohort instead of j_per_edge")
            self.pop = _population.as_population(
                population, j_cohort, n_classes=setting.n_classes,
                max_classes=setting.classes_per_device,
                seed=rng_streams.stream_seed(self.seed, "population"))
            self.j_per_edge = [self.pop.spec.j_cohort] * self.N
        else:
            self.pop = None
            self.j_per_edge = j_per_edge or [setting.j_per_edge] * self.N
        if len(self.j_per_edge) != self.N:
            raise ValueError(
                f"j_per_edge has {len(self.j_per_edge)} entries for "
                f"n_edges={self.N}; a ragged device list must name every "
                "edge exactly once")
        self.D = sum(self.j_per_edge)  # total devices (cohort size in
        #                                population mode)
        # paper semantics: one local iteration = one epoch over the
        # device's own shard — so per-round steps scale inversely with the
        # device count when the total data pool is fixed (Sec. 6.1.5)
        self.steps = steps_per_epoch if steps_per_epoch is not None \
            else max(1, n_train // (self.D * setting.batch_size))

        # ---- data: synthetic class-clustered images, non-IID partition.
        # All host-side randomness is drawn from named SeedSequence streams
        # (core.rng): independent per (seed, stream), collision-free across
        # adjacent seeds — see tests/test_rng_streams.py.
        imgs, labels = class_images(
            n_train + n_test, seed=rng_streams.stream_seed(self.seed, "data"),
            hw=setting.image_hw, n_classes=setting.n_classes)
        # kept as (read-only) numpy views: the device put happens once in
        # build_inputs / the jitted eval — a sweep planner constructs one
        # simulator per grid point, and P per-instance device copies of
        # the test set would pin memory for nothing
        self.test_x = imgs[n_train:]
        self.test_y = labels[n_train:]
        self.train_x, self.train_y = imgs[:n_train], labels[:n_train]
        part_seed = rng_streams.stream_seed(self.seed, "partition")
        if self.pop is None:
            parts = by_class(labels[:n_train], self.N, self.j_per_edge,
                             max_classes=setting.classes_per_device,
                             seed=part_seed)
            self.device_idx = [idx for edge in parts for idx in edge]
        else:
            # population shards are the per-class pools themselves: the
            # occupant's classes select pools, batches sample from them
            # (overlapping shards — see data.partition)
            self.device_idx = None
            self._pool, self._pool_off, self._pool_cnt = class_pools(
                labels[:n_train])
            used = np.unique(self.pop.classes)
            if (self._pool_cnt[used] == 0).any():
                raise ValueError(
                    "population mode needs every assigned class present in "
                    "the train split; increase n_train or n_classes")

        # ---- straggler schedules (submission masks per round)
        rounds = setting.t_global_rounds * setting.k_edge_rounds + 1
        if self.pop is not None:
            self.cohort_ids, self.dev_masks = self._population_schedules(
                rounds, device_stragglers)
        else:
            self.cohort_ids = None
            n_dev_strag = int(round(
                setting.straggler_frac * setting.j_per_edge))
            dev_masks = []
            for e in range(self.N):
                kw = dict(stop_round=setting.permanent_stop_round
                          * setting.k_edge_rounds) \
                    if device_stragglers == "permanent" else {}
                dev_masks.append(strag.from_fraction(
                    rounds, self.j_per_edge[e],
                    n_dev_strag / max(setting.j_per_edge, 1),
                    kind=device_stragglers,
                    seed=rng_streams.stream_seed(self.seed, "dev_masks", e),
                    **kw))
            self.dev_masks = dev_masks                  # list of [rounds, J_e]
        kw = dict(stop_round=setting.permanent_stop_round) \
            if edge_stragglers == "permanent" else {}
        self.edge_masks = strag.from_fraction(
            setting.t_global_rounds + 1, self.N, setting.straggler_frac,
            kind=edge_stragglers,
            seed=rng_streams.stream_seed(self.seed, "edge_masks"),
            **kw)  # [T+1, N]

        # ---- models
        self.specs = cnn_specs(setting.image_hw, 1, setting.n_classes,
                               c1=setting.cnn_c1, c2=setting.cnn_c2)
        # ---- latency fabric: the Sec. 5 model for this deployment plus
        # the consensus chain (protocol, link latency, and shard count all
        # come from the setting, so consensus is a data-batched sweep
        # field — see repro.core.consensus)
        rate_mult = None
        if device_rates is not None:
            if self.pop is not None:
                raise ValueError(
                    "population mode draws per-device rates from the "
                    "store's time_scale profiles; device_rates only "
                    "applies to fixed fleets")
            rate_mult = np.asarray(device_rates, np.float64).reshape(-1)
            if rate_mult.shape != (self.D,):
                raise ValueError(
                    f"device_rates must name every device once "
                    f"(D={self.D}), got shape {rate_mult.shape}")
            if not (rate_mult > 0).all():
                raise ValueError("device_rates must be positive "
                                 "multipliers")
        self.lat = lat.LatencyParams(
            T=setting.t_global_rounds, N=self.N,
            J=int(round(float(np.mean(self.j_per_edge)))),
            lm_device=setting.lm_device, lp_device=setting.lp_device,
            lm_edge=setting.lm_edge, rate_mult=rate_mult)
        self.chain = _consensus.make_chain(
            setting.consensus, self.N,
            link_latency=setting.link_latency, n_shards=setting.n_shards,
            seed=rng_streams.stream_seed(self.seed, "chain"))
        # ---- fault plane (repro.fl.faults): the declarative spec comes
        # from the setting's fault fields unless passed explicitly;
        # fail_leader_at rides the spec as its one-event leader-crash
        # schedule.  Compiled once into per-round event planes on the
        # dedicated "faults" stream — the engine and the chain replay
        # consume the planes as data.
        if faults is None:
            faults = _faults.FaultSpec.from_setting(
                setting, leader_crash_round=fail_leader_at)
        elif faults.leader_crash_round is None and fail_leader_at is not None:
            faults = dataclasses.replace(faults,
                                         leader_crash_round=fail_leader_at)
        self.fault_spec = faults
        self.fail_leader_at = faults.leader_crash_round
        self.fault_schedule = _faults.compile_schedule(
            faults, t_rounds=setting.t_global_rounds,
            k_rounds=setting.k_edge_rounds, n_edges=self.N,
            j_per_edge=list(self.j_per_edge), seed=self.seed)

    # ----------------------------------------------------- population plane
    def _population_schedules(self, rounds: int, device_stragglers: str
                              ) -> tuple[np.ndarray, list[np.ndarray]]:
        """Sample the cohort plan and its slot-keyed straggler masks.

        Returns ``(cohort_ids [T, N, J], dev_masks list of [rounds, J])``.
        All draws are SLOT-keyed uniforms compared against the occupant's
        gathered ``miss_prob`` — so a gathered cohort and a materialized
        ``store.subset`` of the same rows see identical masks (the
        cohort-gather parity invariant, tests/test_population.py).

        Unlike the fixed-membership ``temporary`` schedule (forced return
        the round after a miss), population straggling is i.i.d. Bernoulli
        per round from the occupant's propensity — the fleet-realistic
        model; cold-boot edge rounds (``t <= t_cold_boot``) are never
        missed, matching Alg. 1's assumption.
        """
        s, N, J = self.s, self.N, self.pop.spec.j_cohort
        T, K = s.t_global_rounds, s.k_edge_rounds
        cohort_ids = self.pop.cohort_ids(
            T, N, rng_streams.stream_seed(self.seed, "cohort"))
        if device_stragglers not in ("temporary", "none"):
            raise ValueError(
                "population mode draws straggling from per-device "
                "propensity profiles; device_stragglers must be "
                f"'temporary' or 'none', got {device_stragglers!r}")
        if device_stragglers == "none":
            masks = np.ones((rounds, N, J), dtype=bool)
        else:
            # occupant of global round t holds its slot for all K edge
            # rounds; the trailing schedule row reuses the last cohort
            ids_r = np.repeat(cohort_ids, K, axis=0)
            ids_r = np.concatenate([ids_r, ids_r[-1:]])[:rounds]
            u = rng_streams.stream_rng(self.seed, "dev_masks").random(
                (rounds, N, J))
            masks = u >= self.pop.miss_prob[ids_r]
            masks[:s.t_cold_boot * K] = True
        return cohort_ids, [masks[:, e, :] for e in range(N)]

    def cohort_change(self) -> np.ndarray:
        """``[T, N, J]`` bool — slot occupant changed at the start of global
        round t (always False at t=0 and outside population mode).  Feeds
        the engine's delayed-gradient pending/age reset."""
        T = self.s.t_global_rounds
        J = max(self.j_per_edge)
        if self.cohort_ids is None:
            return np.zeros((T, self.N, J), dtype=bool)
        chg = np.zeros((T, self.N, J), dtype=bool)
        chg[1:] = self.cohort_ids[1:] != self.cohort_ids[:-1]
        return chg

    def cohort_time_scale(self) -> Optional[np.ndarray]:
        """``[T*K, D]`` per-round occupant round-time multipliers for the
        latency fabric (None outside population mode)."""
        if self.cohort_ids is None:
            return None
        K = self.s.k_edge_rounds
        ids_r = np.repeat(self.cohort_ids, K, axis=0)    # [T*K, N, J]
        return self.pop.time_scale[ids_r].reshape(ids_r.shape[0], self.D)

    # ------------------------------------------------------------- batching
    def _epoch_batches(self, rng) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Sample [D, steps, B] batches from each device's own shard."""
        bs = self.s.batch_size
        xs = np.zeros((self.D, self.steps, bs, self.s.image_hw,
                       self.s.image_hw, 1), np.float32)
        ys = np.zeros((self.D, self.steps, bs), np.int32)
        for d, idx in enumerate(self.device_idx):
            if len(idx) == 0:
                continue
            take = rng.choice(idx, size=(self.steps, bs), replace=True)
            xs[d] = self.train_x[take]
            ys[d] = self.train_y[take]
        return jnp.asarray(xs), jnp.asarray(ys)

    def paper_latency(self) -> float:
        """The paper's latency model total (Sec. 5.1.4) for this deployment."""
        return lat.total_latency(self.s.k_edge_rounds, self.lat)

    # ----------------------------------------------------------------- run
    def run(self, progress: bool = False) -> RunResult:
        """Run the deployment on the fully-jitted batched engine.

        Numerically equivalent to ``run_legacy`` (see
        tests/test_engine_parity.py) but executes the whole run as one
        compiled program.  Uses a fresh batch-RNG on the deployment's
        ``"batches"`` stream (``core.rng``), so every ``run()`` call on the
        same instance is identical; the Raft chain, however, advances per
        call exactly like the legacy loop.
        """
        t0 = time.time()
        inp = _engine.build_inputs(self)
        # donated entry: the freshly built hot input planes are handed to
        # the compiled run for buffer reuse (they are rebuilt per call, so
        # nothing else holds them)
        accs, losses, deltas, clock, energy = _engine.run_engine_donated(
            inp, aggregator=self.aggregator, normalize=self.normalize,
            history_dtype=self.history_dtype, kernel_mode=self.kernel_mode)
        accs, losses, deltas, clock, energy = (
            np.asarray(accs), np.asarray(losses), np.asarray(deltas),
            np.asarray(clock), np.asarray(energy))
        if progress:
            for t in range(1, self.s.t_global_rounds + 1):
                if t % 10 == 0 or t == 1:
                    print(f"  t={t:3d} acc={accs[t - 1]:.4f} "
                          f"loss={losses[t - 1]:.4f} "
                          f"clock={clock[t - 1]:.1f}s")
        return RunResult(
            accuracy=accs, loss=losses, grad_norm=deltas,
            wall_time=time.time() - t0, sim_latency=self.paper_latency(),
            blocks=len(self.chain.blocks) - 1,
            chain_valid=self.chain.validate(), sim_clock=clock,
            sim_energy=energy)

    # ------------------------------------------------- checkpointed run
    def run_checkpointed(self, ckpt_dir: str, *, every: int = 10,
                         resume: bool = True,
                         progress: bool = False) -> RunResult:
        """``run()`` in resumable segments of ``every`` global rounds,
        checkpointing after each one (``repro.checkpoint.ckpt`` — atomic
        npz of the engine scan carry plus the per-round outputs so far).

        A killed run restarts from the latest surviving checkpoint and
        finishes **bitwise-identically** to the uninterrupted call: the
        carry is the engine's entire cross-round state, every segment runs
        the same compiled chunk program (``engine.run_engine_chunk``,
        global round numbers threaded through), and the checkpoint
        round-trips every dtype exactly (bf16 histories via raw bits).
        Resume from a **fresh** simulator instance (same constructor
        arguments): the chain replay, fault schedule, and batch/latency
        draws are all rebuilt from their named RNG streams, so the
        rebuilt input planes are byte-identical — whereas reusing a
        half-run instance would replay the chain from an advanced RNG
        state.  Pass ``resume=False`` to ignore (and overwrite) existing
        checkpoints.

        Numerics match ``run()`` (same per-round op sequence; XLA may
        fuse chunk boundaries differently, so cross-entry comparisons are
        allclose, not bitwise — the bitwise contract is between
        checkpointed runs).
        """
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        t0 = time.time()
        T = self.s.t_global_rounds
        inp = _engine.build_inputs(self)
        carry = _engine.init_engine_carry(inp, self.history_dtype)
        keys = ("accuracy", "loss", "delta", "clock", "energy")
        outs = {k: np.zeros((0,), np.float32) for k in keys}
        t_done = 0
        if resume:
            step = _ckpt.latest_step(ckpt_dir)
            if step is not None:
                like = {"carry": carry,
                        "outs": {k: np.zeros((step,), np.float32)
                                 for k in keys}}
                state, _ = _ckpt.restore_checkpoint(ckpt_dir, like, step)
                carry, outs, t_done = state["carry"], state["outs"], step
                if progress:
                    print(f"  resumed from checkpoint @ t={t_done}")
        while t_done < T:
            t1 = min(t_done + every, T)
            seg = _engine.run_engine_chunk(
                _engine.slice_rounds(inp, t_done, t1), carry,
                jnp.int32(t_done), aggregator=self.aggregator,
                normalize=self.normalize, history_dtype=self.history_dtype,
                kernel_mode=self.kernel_mode)
            (acc, loss, delta, clock, energy), carry = seg
            for k, v in zip(keys, (acc, loss, delta, clock, energy)):
                outs[k] = np.concatenate([outs[k],
                                          np.asarray(v, np.float32)])
            t_done = t1
            _ckpt.save_checkpoint(ckpt_dir, t_done,
                                  {"carry": carry, "outs": outs},
                                  metadata={"t": t_done})
            if progress:
                print(f"  t={t_done:3d} acc={outs['accuracy'][-1]:.4f} "
                      f"clock={outs['clock'][-1]:.1f}s  [checkpointed]")
        return RunResult(
            accuracy=outs["accuracy"], loss=outs["loss"],
            grad_norm=outs["delta"], wall_time=time.time() - t0,
            sim_latency=self.paper_latency(),
            blocks=len(self.chain.blocks) - 1,
            chain_valid=self.chain.validate(), sim_clock=outs["clock"],
            sim_energy=outs["energy"])

    # ---------------------------------------------------------- legacy run
    def run_legacy(self, progress: bool = False) -> RunResult:
        """The original per-edge Python loop (numerics reference).

        Uses a fresh per-run batch generator on the same ``"batches"``
        stream as the engine path — repeated or interleaved ``run()`` /
        ``run_legacy()`` calls on one instance are all batch-identical.
        (Previously this consumed a shared mutable ``self.rng``, so a
        second legacy run silently diverged from the first.)
        """
        if self.pop is not None:
            raise ValueError(
                "population mode runs on the engine path only; use run()")
        if self.fault_spec.any_faults:
            raise ValueError(
                "stochastic fault injection (repro.fl.faults) runs on the "
                "engine path only; use run()")
        s = self.s
        t0 = time.time()
        batch_rng = rng_streams.stream_rng(self.seed, "batches")
        # device-resident test set for the per-round eval (self.test_x is
        # a numpy view; re-committing it every round would tax the loop)
        test_x, test_y = jnp.asarray(self.test_x), jnp.asarray(self.test_y)
        key = jax.random.key(self.seed)
        global_w = init_from_specs(self.specs, key)
        device_w = _bcast_like(global_w, self.D)        # stacked [D, ...]

        # per-edge device histories + the global edge-model history
        edge_slices = np.cumsum([0] + self.j_per_edge)
        dev_hist = None      # stacked [N? ragged] -> list per edge
        glob_hist = None
        dev_last = None      # d_fedavg last-submission stores
        glob_last = None

        accs, losses, deltas = [], [], []
        prev_global = global_w
        round_ctr = 0        # edge-round counter (t*K + k) for masks/lr

        failed_edge: Optional[int] = None
        # failover availability is DERIVED per run, never written back to
        # self.edge_masks — a repeated run sees pristine simulator state
        # (matches the engine path's replay-derived edge_avail plane)
        edge_avail = np.ones(self.N, dtype=bool)
        for t in range(1, s.t_global_rounds + 1):
            # ---- Raft: overlap leader election with the K edge rounds
            _, elect_t = self.chain.elect_leader()
            if self.fail_leader_at is not None and t == self.fail_leader_at:
                # single-point-of-failure drill: crash the elected leader;
                # Raft re-elects among the surviving edges (commit_block
                # below triggers the election) and BHFL keeps training
                failed_edge = self.chain.leader
                self.chain.fail_node(failed_edge)
            if failed_edge is not None:
                edge_avail[failed_edge] = False
            edge_models = None
            for k in range(1, s.k_edge_rounds + 1):
                lr = paper_lr(jnp.asarray(round_ctr), s.lr0, s.lr_decay)
                bx, by = self._epoch_batches(batch_rng)
                device_w, dev_loss = _train_epoch(device_w, bx, by, lr)

                # per-edge aggregation with this edge round's masks
                new_edge_models, new_hists, new_lasts = [], [], []
                for e in range(self.N):
                    sl = slice(edge_slices[e], edge_slices[e + 1])
                    ws = _index(device_w, sl)
                    mask = jnp.asarray(self.dev_masks[e][round_ctr])
                    agg, hist_e, last_e = self._edge_agg(
                        ws, mask, t,
                        None if dev_hist is None else dev_hist[e],
                        None if dev_last is None else dev_last[e])
                    new_edge_models.append(agg)
                    new_hists.append(hist_e)
                    new_lasts.append(last_e)
                dev_hist, dev_last = new_hists, new_lasts
                edge_models = _stack(new_edge_models)   # [N, ...]
                # devices sync to their edge model for the next epoch
                device_w = _stack([
                    _index(edge_models, e)
                    for e in range(self.N) for _ in range(self.j_per_edge[e])])
                round_ctr += 1

            # ---- global aggregation on the leader + block commit
            emask = jnp.asarray(self.edge_masks[t - 1] & edge_avail)
            j_arr = jnp.asarray(self.j_per_edge, jnp.float32)
            global_w, glob_hist, glob_last = self._global_agg(
                edge_models, emask, t, glob_hist, glob_last, j_arr)
            device_w = _bcast_like(global_w, self.D)
            self.chain.commit_block(f"edges@t={t}", f"global@t={t}")

            # ---- metrics
            acc = float(cnn_accuracy(global_w, test_x, test_y))
            accs.append(acc)
            losses.append(float(jnp.mean(dev_loss)))
            dn = float(sum(float(jnp.sum(jnp.square(a - b)))
                           for a, b in zip(jax.tree.leaves(global_w),
                                           jax.tree.leaves(prev_global))) ** 0.5)
            deltas.append(dn)
            prev_global = global_w
            if progress and (t % 10 == 0 or t == 1):
                print(f"  t={t:3d} acc={acc:.4f} loss={losses[-1]:.4f}")

        return RunResult(
            accuracy=np.asarray(accs), loss=np.asarray(losses),
            grad_norm=np.asarray(deltas), wall_time=time.time() - t0,
            sim_latency=self.paper_latency(),
            blocks=len(self.chain.blocks) - 1,
            chain_valid=self.chain.validate())

    # ------------------------------------------------------- agg dispatch
    def _edge_agg(self, ws, mask, t, hist, last):
        return self._agg(ws, mask, t, hist, last, part_weights=None)

    def _global_agg(self, ws, mask, t, hist, last, j_arr):
        return self._agg(ws, mask, t, hist, last, part_weights=j_arr)

    def _agg(self, ws, mask, t, hist, last, part_weights):
        """Returns (aggregate, new history, new last-store)."""
        s = self.s
        n = int(mask.shape[0])
        if self.aggregator == "hieavg":
            if hist is None:                       # first-ever submission
                hist = hieavg.init_history(ws)
            if t <= s.t_cold_boot:                 # Alg. 1: cold boot
                if part_weights is None:
                    agg = hieavg.edge_aggregate_cold(ws)
                else:
                    agg = hieavg.global_aggregate_cold(ws, part_weights)
                hist = hieavg.update_history(hist, ws, mask)
                return agg, hist, last
            if part_weights is None:
                agg, hist = hieavg.edge_aggregate(
                    ws, mask, hist, gamma0=s.gamma0, lam=s.lam,
                    normalize=self.normalize)
            else:
                agg, hist = hieavg.global_aggregate(
                    ws, mask, hist, part_weights, gamma0=s.gamma0,
                    lam=s.lam, normalize=self.normalize)
            return agg, hist, last
        if self.aggregator == "t_fedavg":
            return baselines.t_fedavg(ws, mask, part_weights), hist, last
        if self.aggregator == "d_fedavg":
            if last is None:
                last = jax.tree.map(jnp.zeros_like, ws)
                # first round: treat everyone as present for the store
                agg, last = baselines.d_fedavg(
                    ws, jnp.ones_like(mask), last, part_weights)
                return agg, hist, last
            agg, last = baselines.d_fedavg(ws, mask, last, part_weights)
            return agg, hist, last
        if self.aggregator == "delayed_grad":
            if last is None:
                # first round: everyone counts present (nothing in flight)
                last = (jax.tree.map(jnp.zeros_like, ws),
                        jnp.zeros((n,), jnp.float32))
                mask = jnp.ones_like(mask)
            pending, age = last
            agg, pending, age = baselines.delayed_grad(
                ws, mask, pending, age, s.staleness_discount,
                float(s.delay_delta), part_weights)
            return agg, hist, (pending, age)
        if self.aggregator == "fedavg":
            return baselines.fedavg(ws, part_weights), hist, last
        raise ValueError(f"unknown aggregator {self.aggregator!r}")


# --------------------------------------------------------------- shortcuts
def run_comparison(setting: BHFLSetting = BHFLSetting(),
                   kinds: tuple[str, ...] = ("hieavg", "t_fedavg", "d_fedavg"),
                   straggler_kind: str = "temporary",
                   include_oracle: bool = True, **kw) -> dict[str, RunResult]:
    """Fig. 2-style comparison: same data/seed, different aggregators."""
    out = {}
    if include_oracle:
        out["wo_stragglers"] = BHFLSimulator(
            setting, "fedavg", "none", "none", **kw).run()
    for kind in kinds:
        out[kind] = BHFLSimulator(
            setting, kind, straggler_kind, straggler_kind, **kw).run()
    return out
