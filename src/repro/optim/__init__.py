from .sgd import (sgd_init, sgd_step, adam_init, adam_step, paper_lr,
                  OptState)

__all__ = ["sgd_init", "sgd_step", "adam_init", "adam_step", "paper_lr",
           "OptState"]
