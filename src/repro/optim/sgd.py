"""Optimizers + the paper's decaying learning-rate schedule.

The paper assumes a dynamic learning rate  eta^{t,k} = 1 / (eta0 + d*(t*K+k))
(Sec. 4.1) — ``paper_lr`` implements exactly that, where ``step = t*K + k``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    mu: PyTree                  # momentum (sgd) / first moment (adam)
    nu: Optional[PyTree]        # second moment (adam) or None
    count: jnp.ndarray


def paper_lr(step: jnp.ndarray, eta0: float = 1e-3, decay: float = 0.90,
             k_total: Optional[int] = None) -> jnp.ndarray:
    """eta^{t,k} = 1 / (1/eta0 + d*step): the paper's form 1/(eta0+d*(tK+k))
    re-parameterized so eta(0) == eta0 (the paper's 'initial learning rate
    0.001' with decay d)."""
    del k_total
    return 1.0 / (1.0 / eta0 + decay * step.astype(jnp.float32))


def sgd_init(params: PyTree) -> OptState:
    return OptState(mu=jax.tree.map(jnp.zeros_like, params), nu=None,
                    count=jnp.zeros((), jnp.int32))


def sgd_step(params: PyTree, grads: PyTree, state: OptState, lr: jnp.ndarray,
             momentum: float = 0.0) -> tuple[PyTree, OptState]:
    if momentum:
        mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
        upd = mu
    else:
        mu, upd = state.mu, grads
    new = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) - lr * u.astype(jnp.float32))
        .astype(p.dtype), params, upd)
    return new, OptState(mu=mu, nu=None, count=state.count + 1)


def adam_init(params: PyTree) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(mu=z, nu=jax.tree.map(jnp.zeros_like, z),
                    count=jnp.zeros((), jnp.int32))


def adam_step(params: PyTree, grads: PyTree, state: OptState, lr: jnp.ndarray,
              b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
              ) -> tuple[PyTree, OptState]:
    c = state.count + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                      * jnp.square(g.astype(jnp.float32)), state.nu, grads)
    bc1 = 1 - b1 ** c.astype(jnp.float32)
    bc2 = 1 - b2 ** c.astype(jnp.float32)

    def upd(p, m, v):
        step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    return jax.tree.map(upd, params, mu, nu), OptState(mu=mu, nu=nu, count=c)
