"""Fused coefficient-weighted aggregate Pallas kernel (cold boot + baselines).

The cold-boot rounds and the non-HieAvg baseline aggregators were the
last round phases still paying XLA round trips over the ``[n, L]``
stacked weights: the cold-start mean (``hieavg.*_aggregate_cold``),
FedAvg, and the delayed-gradient mix are all instances of ONE scheme —
a coefficient-weighted sum over the participant axis:

    agg = Σ_n  ca[n] · w[n]                      (single-operand form)
    agg = Σ_n  ca[n] · w[n] + cb[n] · aux[n]     (pair form)

The pair form covers delayed-gradient aggregation, where a missing
device contributes its stale *pending* update (``aux``) instead of a
fresh one.  The tiny [n] coefficient vectors (validity normalization,
staleness discounts) are computed in XLA outside; the kernel does the
heavy [n, L] weighted reduction in one HBM pass per leaf, identical
tiling to ``hieavg_agg`` (grid over the flat parameter axis, [n, TILE]
blocks in VMEM).

Zero-coefficient padded slots — sweep-fabric padding, invalid devices,
all-miss cold rounds — contribute ``0 · w = 0`` exactly, so padding
stays a numeric no-op and a vmapped batch of edges (Pallas prepends the
``[P, N]`` axes as grid dims) needs no masking inside the kernel.

Outputs are f32 regardless of operand dtype, matching the XLA reference
paths (f32 coefficients promote the product; ``history_dtype=bf16``
runs still aggregate in f32).  Oracles: ``ref.coef_agg_ref`` /
``ref.coef_agg_pair_ref``.  Backend selection + the coefficient recipes
for each aggregator live in ``kernels.dispatch``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret

TILE = 2048


def _kernel1(w_ref, c_ref, agg_ref):
    """One [n, TILE] block: agg = Σ_n c[n] · w[n]."""
    w = w_ref[...].astype(jnp.float32)
    c = c_ref[0, :][:, None]                     # [n, 1]
    agg_ref[...] = jnp.sum(c * w, axis=0, keepdims=True)


def _kernel2(w_ref, aux_ref, c_ref, agg_ref):
    """One [n, TILE] block: agg = Σ_n ca[n] · w[n] + cb[n] · aux[n]."""
    f32 = jnp.float32
    w = w_ref[...].astype(f32)
    aux = aux_ref[...].astype(f32)
    ca = c_ref[0, :][:, None]
    cb = c_ref[1, :][:, None]
    agg_ref[...] = jnp.sum(ca * w + cb * aux, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def coef_agg(w: jnp.ndarray, coef: jnp.ndarray,
             interpret: bool | None = None) -> jnp.ndarray:
    """Fused ``Σ_n coef[n] · w[n]`` on one flat [n, L] leaf → f32 [L]."""
    if interpret is None:
        interpret = default_interpret()
    n, l = w.shape
    pad = (-l) % TILE
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
    lp = l + pad
    cvec = coef.astype(jnp.float32)[None, :]                 # [1, n]
    agg = pl.pallas_call(
        _kernel1,
        grid=(lp // TILE,),
        in_specs=[
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, lp), jnp.float32),
        interpret=interpret,
    )(w, cvec)
    return agg[0, :l]


@functools.partial(jax.jit, static_argnames=("interpret",))
def coef_agg_pair(w: jnp.ndarray, aux: jnp.ndarray, ca: jnp.ndarray,
                  cb: jnp.ndarray, interpret: bool | None = None
                  ) -> jnp.ndarray:
    """Fused ``Σ_n ca[n]·w[n] + cb[n]·aux[n]`` on flat [n, L] → f32 [L]."""
    if interpret is None:
        interpret = default_interpret()
    n, l = w.shape
    pad = (-l) % TILE
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        aux = jnp.pad(aux, ((0, 0), (0, pad)))
    lp = l + pad
    cvec = jnp.stack([ca.astype(jnp.float32), cb.astype(jnp.float32)])
    agg = pl.pallas_call(
        _kernel2,
        grid=(lp // TILE,),
        in_specs=[
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
            pl.BlockSpec((2, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, lp), jnp.float32),
        interpret=interpret,
    )(w, aux, cvec)
    return agg[0, :l]
