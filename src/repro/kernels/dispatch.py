"""Kernel-plane backend dispatch — who runs a fused op, and how.

Every compute hot-spot with a Pallas kernel has THREE executable forms:

  * ``pallas``    — the compiled ``pallas_call`` (TPU/GPU; fails to lower
                    on CPU, which has no Mosaic backend),
  * ``interpret`` — the same kernel through the Pallas interpreter
                    (jax-level emulation: traceable, jittable, correct
                    everywhere, slower — the CPU validation path),
  * ``xla``       — the pure-jnp reference path (``core.hieavg``'s fused
                    ``_mix_and_update`` tree.map / the plain SGD tree.map),
                    which XLA fuses well on CPU.

This module is the single place that picks between them.  The knob is a
``kernel_mode`` string threaded ``BHFLSimulator``/``run_sweep`` →
``run_engine`` (like ``history_dtype``):

  * ``"auto"``      — ``pallas`` on TPU/GPU, ``xla`` on CPU.  The default
                      everywhere: accelerators get the one-HBM-pass fused
                      kernels, CPU keeps the XLA path with zero overhead
                      (never the interpreter loop).
  * ``"pallas"`` / ``"interpret"`` / ``"xla"`` — force a path (tests pin
                      ``interpret`` vs ``xla`` engine parity on CPU).

``default_interpret()`` is the companion policy for DIRECT kernel calls
(``ops.flash_attention``, ``hieavg_agg`` benchmarks): when the caller
passes ``interpret=None`` the kernel compiles on TPU/GPU and interprets on
CPU — previously ``interpret=True`` was hard-coded "until the launch layer
flips it off", which nothing ever did, so real hardware silently ran the
interpreter.

Layering: this module imports only jax + ``core.hieavg`` at module level
and pulls the kernel wrappers (``ops``) in lazily, so the kernel modules
may import ``default_interpret`` from here without a cycle.

The dispatch entry points (``edge_aggregate_batched``,
``global_aggregate``, ``sgd_update``) mirror the engine's calling
conventions exactly — batched ``[N, J, ...]`` stacked trees with validity
masks, traced ``gamma0``/``lam`` scalars — and guarantee the same
padded-slot no-op contract as the XLA path (zero part-weight padding
contributes exactly nothing; see docs/ARCHITECTURE.md §Kernel plane).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import baselines, hieavg
from repro.core.hieavg import History

PyTree = Any

#: The engine round phases with a fused kernel, in round order.  Under a
#: fused mode (``pallas``/``interpret``) every phase listed here runs in
#: a Pallas kernel; under ``xla`` all run the pure-jnp reference paths.
#: (``t_fedavg``/``d_fedavg`` — legacy baselines outside the switched
#: set — and the tiny history-bookkeeping updates stay XLA by design.)
ROUND_PHASES = ("train_conv_fwd_bwd", "sgd_update", "warm_edge_aggregate",
                "warm_global_aggregate", "cold_boot_aggregate",
                "fedavg_aggregate", "delayed_grad_aggregate", "eval_head")


def fused_phase_coverage(mode: str = "auto") -> dict:
    """Which round phases run fused under ``mode`` (resolved) — the
    benchmarks' coverage column (`padded_flop_frac`-style)."""
    fused = resolve_kernel_mode(mode) in ("pallas", "interpret")
    return {phase: fused for phase in ROUND_PHASES}

#: The accepted ``kernel_mode`` values, in resolution order.
KERNEL_MODES = ("auto", "pallas", "interpret", "xla")

#: Backends with a real Pallas lowering (Mosaic / Triton).
_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def resolve_kernel_mode(mode: str = "auto") -> str:
    """Resolve a ``kernel_mode`` knob to a concrete path.

    ``"auto"`` → ``"pallas"`` when the default jax backend can compile
    Pallas kernels (TPU/GPU), else ``"xla"`` — never ``"interpret"``: the
    interpreter is a validation tool, not a production path.  Explicit
    modes pass through; unknown strings raise naming the valid set.
    Callers resolve once (host-side) so jit caches key on the concrete
    mode, not on ``"auto"``.
    """
    if mode not in KERNEL_MODES:
        raise ValueError(
            f"unknown kernel_mode {mode!r}; expected one of {KERNEL_MODES}")
    if mode != "auto":
        return mode
    return "pallas" if jax.default_backend() in _COMPILED_BACKENDS else "xla"


def default_interpret() -> bool:
    """Interpret flag for direct kernel calls when the caller didn't pick:
    compile on TPU/GPU, interpret on CPU (where Pallas cannot lower)."""
    return jax.default_backend() not in _COMPILED_BACKENDS


def _interpret(mode: str) -> bool:
    """The ``pallas_call`` interpret flag for a resolved fused mode."""
    return mode == "interpret"


# --------------------------------------------------------- engine dispatch
def edge_aggregate_batched(stacked_w: PyTree, mask: jnp.ndarray,
                           history: History, valid: jnp.ndarray,
                           gamma0, lam, normalize: bool = False, *,
                           mode: str = "auto") -> tuple[PyTree, History]:
    """Eq. (4) for all N edges — ``hieavg.edge_aggregate_batched``
    semantics, routed through the fused kernel when ``mode`` says so.

    stacked_w leaves ``[N, J, ...]``; mask/valid ``[N, J]``; history
    likewise; ``gamma0``/``lam`` may be traced.  Padded slots
    (``valid`` False) carry zero part weight on every path.
    """
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return hieavg.edge_aggregate_batched(stacked_w, mask, history,
                                             valid, gamma0, lam, normalize)
    from . import ops
    return ops.fused_edge_aggregate_batched(
        stacked_w, mask, history, valid, gamma0, lam, normalize,
        interpret=_interpret(mode))


def global_aggregate(stacked_w: PyTree, mask: jnp.ndarray, history: History,
                     part_weights: jnp.ndarray, gamma0, lam,
                     normalize: bool = False, *, mode: str = "auto"
                     ) -> tuple[PyTree, History]:
    """Eq. (5) on the leader — ``hieavg.aggregate`` semantics (traced
    ``part_weights``/``gamma0``/``lam``), fused-kernel routed."""
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return hieavg.aggregate(stacked_w, mask, history, part_weights,
                                gamma0, lam, normalize)
    from . import ops
    return ops.fused_mix_and_update(stacked_w, mask, history, part_weights,
                                    gamma0, lam, normalize,
                                    interpret=_interpret(mode))


def sgd_update(params: PyTree, grads: PyTree, scale, *,
               mode: str = "auto") -> PyTree:
    """The train-step inner update ``w - scale * g`` per leaf.

    ``scale`` is the (traced) lr × step-validity product — a padded sweep
    step passes 0 and the update is exact identity on every path.  The
    fused path does the read-modify-write in one pass per ``[D, L]`` leaf
    (oracle: ``ref.sgd_update_ref``); ``xla`` is the engine's original
    ``tree.map``, bit-identical to what ``run_engine`` always did.
    """
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return jax.tree.map(lambda w, g: w - scale * g, params, grads)
    from . import ops
    return ops.fused_sgd_update(params, grads, scale,
                                interpret=_interpret(mode))


def conv3x3_bias_relu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
                      mode: str = "auto") -> jnp.ndarray:
    """The CNN conv block ``relu(conv3x3_same(x, w) + b)``.

    The fused path runs the im2col matmul with bias+ReLU epilogue (and
    both backward matmuls) in Pallas; ``xla`` is the engine's original
    ``_conv3x3_same_im2col`` einsum + separate bias/ReLU, bit-identical
    to what ``cnn_apply_fast`` always did.
    """
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        from repro.models.cnn import _conv3x3_same_im2col
        return jax.nn.relu(_conv3x3_same_im2col(x, w) + b)
    from . import ops
    return ops.conv3x3_bias_relu(x, w, b, interpret=_interpret(mode))


def eval_head(feats: jnp.ndarray, wmat: jnp.ndarray, bias: jnp.ndarray,
              labels: jnp.ndarray, *, mode: str = "auto") -> jnp.ndarray:
    """Correct-prediction count of the classifier head (scalar int32).

    The fused path folds logits → argmax → compare → count into the
    matmul tiles; ``xla`` is the plain three-op chain.
    """
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        logits = feats @ wmat + bias
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.sum((pred == labels.astype(jnp.int32)).astype(jnp.int32))
    from . import ops
    return ops.eval_head(feats, wmat, bias, labels,
                         interpret=_interpret(mode))


# ------------------------------------------------- cold boot + baselines
# All three entries below are instances of the generalized coefficient
# aggregate (``kernels.coef_agg``): the tiny [n] coefficient recipe is
# computed here in XLA — matching each reference path's normalization
# bit-for-bit — and the heavy [n, L] weighted reduction runs fused.

def edge_aggregate_cold_batched(stacked_w: PyTree, valid: jnp.ndarray, *,
                                mode: str = "auto") -> PyTree:
    """Cold-boot edge mean for all N edges (eq. 2) —
    ``hieavg.edge_aggregate_cold_batched`` semantics, kernel-routed.

    stacked_w leaves ``[N, J, ...]``; ``valid`` [N, J].  Padded slots
    carry zero coefficient; an all-invalid edge aggregates to exact
    zeros (the 1e-12 denominator floor), never a division by zero.
    """
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return hieavg.edge_aggregate_cold_batched(stacked_w, valid)
    from . import ops
    v = valid.astype(jnp.float32)
    pw = v / jnp.maximum(jnp.sum(v, axis=-1, keepdims=True), 1e-12)
    fn = functools.partial(ops.fused_coef_aggregate,
                           interpret=_interpret(mode))
    return jax.vmap(fn)(stacked_w, pw)


def global_aggregate_cold(stacked_w: PyTree, j_per_edge: jnp.ndarray, *,
                          mode: str = "auto") -> PyTree:
    """Cold-boot global J_i-weighted mean (eq. 3) —
    ``hieavg.global_aggregate_cold`` semantics, kernel-routed."""
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return hieavg.global_aggregate_cold(stacked_w, j_per_edge)
    from . import ops
    pw = j_per_edge.astype(jnp.float32) \
        / jnp.maximum(jnp.sum(j_per_edge), 1e-12)
    return ops.fused_coef_aggregate(stacked_w, pw,
                                    interpret=_interpret(mode))


def fedavg(stacked_w: PyTree, part_weights: jnp.ndarray, *,
           mode: str = "auto") -> PyTree:
    """Weighted FedAvg — ``baselines.fedavg`` semantics, kernel-routed."""
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return baselines.fedavg(stacked_w, part_weights)
    from . import ops
    coef = part_weights / jnp.maximum(jnp.sum(part_weights), 1e-12)
    return ops.fused_coef_aggregate(stacked_w, coef,
                                    interpret=_interpret(mode))


def delayed_grad(stacked_w: PyTree, mask: jnp.ndarray, pending: PyTree,
                 age: jnp.ndarray, beta, delta,
                 part_weights: jnp.ndarray, *, mode: str = "auto"
                 ) -> tuple[PyTree, PyTree, jnp.ndarray]:
    """Delayed-gradient aggregation — ``baselines.delayed_grad``
    semantics, kernel-routed.

    The aggregate is the pair form of the coefficient kernel: a present
    slot contributes ``coef·w``, a missing one its staleness-discounted
    pending update ``coef·p`` — the fill + weighted mean in one pass.
    The tiny pending/age store updates stay XLA (pure data movement).
    """
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return baselines.delayed_grad(stacked_w, mask, pending, age,
                                      beta, delta, part_weights)
    from . import ops
    m = mask.astype(jnp.float32)
    k_prime = age + 1.0
    stale_c = (beta ** k_prime) * (k_prime <= delta).astype(jnp.float32)
    coef = part_weights * (m + (1.0 - m) * stale_c)
    coef = coef / jnp.maximum(jnp.sum(coef), 1e-12)
    agg = ops.fused_coef_aggregate_pair(stacked_w, pending, coef * m,
                                        coef * (1.0 - m),
                                        interpret=_interpret(mode))
    new_age = (age + 1.0) * (1.0 - m)
    return agg, stacked_w, new_age
