"""Kernel-plane backend dispatch — who runs a fused op, and how.

Every compute hot-spot with a Pallas kernel has THREE executable forms:

  * ``pallas``    — the compiled ``pallas_call`` (TPU/GPU; fails to lower
                    on CPU, which has no Mosaic backend),
  * ``interpret`` — the same kernel through the Pallas interpreter
                    (jax-level emulation: traceable, jittable, correct
                    everywhere, slower — the CPU validation path),
  * ``xla``       — the pure-jnp reference path (``core.hieavg``'s fused
                    ``_mix_and_update`` tree.map / the plain SGD tree.map),
                    which XLA fuses well on CPU.

This module is the single place that picks between them.  The knob is a
``kernel_mode`` string threaded ``BHFLSimulator``/``run_sweep`` →
``run_engine`` (like ``history_dtype``):

  * ``"auto"``      — ``pallas`` on TPU/GPU, ``xla`` on CPU.  The default
                      everywhere: accelerators get the one-HBM-pass fused
                      kernels, CPU keeps the XLA path with zero overhead
                      (never the interpreter loop).
  * ``"pallas"`` / ``"interpret"`` / ``"xla"`` — force a path (tests pin
                      ``interpret`` vs ``xla`` engine parity on CPU).

``default_interpret()`` is the companion policy for DIRECT kernel calls
(``ops.flash_attention``, ``hieavg_agg`` benchmarks): when the caller
passes ``interpret=None`` the kernel compiles on TPU/GPU and interprets on
CPU — previously ``interpret=True`` was hard-coded "until the launch layer
flips it off", which nothing ever did, so real hardware silently ran the
interpreter.

Layering: this module imports only jax + ``core.hieavg`` at module level
and pulls the kernel wrappers (``ops``) in lazily, so the kernel modules
may import ``default_interpret`` from here without a cycle.

The dispatch entry points (``edge_aggregate_batched``,
``global_aggregate``, ``sgd_update``) mirror the engine's calling
conventions exactly — batched ``[N, J, ...]`` stacked trees with validity
masks, traced ``gamma0``/``lam`` scalars — and guarantee the same
padded-slot no-op contract as the XLA path (zero part-weight padding
contributes exactly nothing; see docs/ARCHITECTURE.md §Kernel plane).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hieavg
from repro.core.hieavg import History

PyTree = Any

#: The accepted ``kernel_mode`` values, in resolution order.
KERNEL_MODES = ("auto", "pallas", "interpret", "xla")

#: Backends with a real Pallas lowering (Mosaic / Triton).
_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def resolve_kernel_mode(mode: str = "auto") -> str:
    """Resolve a ``kernel_mode`` knob to a concrete path.

    ``"auto"`` → ``"pallas"`` when the default jax backend can compile
    Pallas kernels (TPU/GPU), else ``"xla"`` — never ``"interpret"``: the
    interpreter is a validation tool, not a production path.  Explicit
    modes pass through; unknown strings raise naming the valid set.
    Callers resolve once (host-side) so jit caches key on the concrete
    mode, not on ``"auto"``.
    """
    if mode not in KERNEL_MODES:
        raise ValueError(
            f"unknown kernel_mode {mode!r}; expected one of {KERNEL_MODES}")
    if mode != "auto":
        return mode
    return "pallas" if jax.default_backend() in _COMPILED_BACKENDS else "xla"


def default_interpret() -> bool:
    """Interpret flag for direct kernel calls when the caller didn't pick:
    compile on TPU/GPU, interpret on CPU (where Pallas cannot lower)."""
    return jax.default_backend() not in _COMPILED_BACKENDS


def _interpret(mode: str) -> bool:
    """The ``pallas_call`` interpret flag for a resolved fused mode."""
    return mode == "interpret"


# --------------------------------------------------------- engine dispatch
def edge_aggregate_batched(stacked_w: PyTree, mask: jnp.ndarray,
                           history: History, valid: jnp.ndarray,
                           gamma0, lam, normalize: bool = False, *,
                           mode: str = "auto") -> tuple[PyTree, History]:
    """Eq. (4) for all N edges — ``hieavg.edge_aggregate_batched``
    semantics, routed through the fused kernel when ``mode`` says so.

    stacked_w leaves ``[N, J, ...]``; mask/valid ``[N, J]``; history
    likewise; ``gamma0``/``lam`` may be traced.  Padded slots
    (``valid`` False) carry zero part weight on every path.
    """
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return hieavg.edge_aggregate_batched(stacked_w, mask, history,
                                             valid, gamma0, lam, normalize)
    from . import ops
    return ops.fused_edge_aggregate_batched(
        stacked_w, mask, history, valid, gamma0, lam, normalize,
        interpret=_interpret(mode))


def global_aggregate(stacked_w: PyTree, mask: jnp.ndarray, history: History,
                     part_weights: jnp.ndarray, gamma0, lam,
                     normalize: bool = False, *, mode: str = "auto"
                     ) -> tuple[PyTree, History]:
    """Eq. (5) on the leader — ``hieavg.aggregate`` semantics (traced
    ``part_weights``/``gamma0``/``lam``), fused-kernel routed."""
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return hieavg.aggregate(stacked_w, mask, history, part_weights,
                                gamma0, lam, normalize)
    from . import ops
    return ops.fused_mix_and_update(stacked_w, mask, history, part_weights,
                                    gamma0, lam, normalize,
                                    interpret=_interpret(mode))


def sgd_update(params: PyTree, grads: PyTree, scale, *,
               mode: str = "auto") -> PyTree:
    """The train-step inner update ``w - scale * g`` per leaf.

    ``scale`` is the (traced) lr × step-validity product — a padded sweep
    step passes 0 and the update is exact identity on every path.  The
    fused path does the read-modify-write in one pass per ``[D, L]`` leaf
    (oracle: ``ref.sgd_update_ref``); ``xla`` is the engine's original
    ``tree.map``, bit-identical to what ``run_engine`` always did.
    """
    mode = resolve_kernel_mode(mode)
    if mode == "xla":
        return jax.tree.map(lambda w, g: w - scale * g, params, grads)
    from . import ops
    return ops.fused_sgd_update(params, grads, scale,
                                interpret=_interpret(mode))
