"""Kernel plane — fused Pallas kernels + the backend-dispatch layer.

One module per kernel, each with a pure-jnp oracle in ``ref.py`` that
defines its semantics contract (tests sweep shapes/dtypes against it):

  * ``hieavg_agg``      — fused HieAvg mix + history update (eq. 4/5),
                          one HBM pass instead of XLA's ~7,
  * ``sgd_update``      — the train-step masked SGD update,
  * ``conv3x3``         — the CNN conv block: im2col matmul with fused
                          bias + ReLU epilogue and a fused backward
                          (custom VJP) — the train step's hottest op,
  * ``eval_head``       — classifier-head eval: logits → argmax →
                          correct-count in one pass over the test set,
  * ``coef_agg``        — generalized coefficient-weighted aggregate
                          shared by the cold-boot means, FedAvg and the
                          delayed-gradient mix,
  * ``flash_attention`` — blocked online-softmax attention (the LLM
                          serving path).

``ops.py`` holds the jit'd pytree-level wrappers (batched/vmapped entry
points matching the engine's dense ``[N, J, ...]`` + validity-mask
conventions); ``dispatch.py`` is the backend policy — the
``kernel_mode = "auto" | "pallas" | "interpret" | "xla"`` knob that routes
the engine's hot path to the compiled kernel on TPU/GPU, the pure-XLA
reference on CPU, or the Pallas interpreter for validation.  With the
conv/eval/cold-boot kernels the fused modes now cover every heavy phase
of the engine round (``dispatch.ROUND_PHASES``).  See
docs/ARCHITECTURE.md §Kernel plane for the layer contract.
"""
from .dispatch import (KERNEL_MODES, ROUND_PHASES, default_interpret,
                       fused_phase_coverage, resolve_kernel_mode)
from .ops import (conv3x3_bias_relu, eval_head, flash_attention,
                  fused_coef_aggregate, fused_coef_aggregate_pair,
                  fused_edge_aggregate, fused_edge_aggregate_batched,
                  fused_mix_and_update, fused_sgd_update)

__all__ = [
    "KERNEL_MODES", "ROUND_PHASES", "default_interpret",
    "fused_phase_coverage", "resolve_kernel_mode",
    "conv3x3_bias_relu", "eval_head", "flash_attention",
    "fused_coef_aggregate", "fused_coef_aggregate_pair",
    "fused_edge_aggregate", "fused_edge_aggregate_batched",
    "fused_mix_and_update", "fused_sgd_update",
]
