"""Kernel plane — fused Pallas kernels + the backend-dispatch layer.

One module per kernel, each with a pure-jnp oracle in ``ref.py`` that
defines its semantics contract (tests sweep shapes/dtypes against it):

  * ``hieavg_agg``      — fused HieAvg mix + history update (eq. 4/5),
                          one HBM pass instead of XLA's ~7,
  * ``sgd_update``      — the train-step masked SGD update,
  * ``flash_attention`` — blocked online-softmax attention (the LLM
                          serving path).

``ops.py`` holds the jit'd pytree-level wrappers (batched/vmapped entry
points matching the engine's dense ``[N, J, ...]`` + validity-mask
conventions); ``dispatch.py`` is the backend policy — the
``kernel_mode = "auto" | "pallas" | "interpret" | "xla"`` knob that routes
the engine's hot path to the compiled kernel on TPU/GPU, the pure-XLA
reference on CPU, or the Pallas interpreter for validation.  See
docs/ARCHITECTURE.md §Kernel plane for the layer contract.
"""
from .dispatch import KERNEL_MODES, default_interpret, resolve_kernel_mode
from .ops import (flash_attention, fused_edge_aggregate,
                  fused_edge_aggregate_batched, fused_mix_and_update,
                  fused_sgd_update)

__all__ = [
    "KERNEL_MODES", "default_interpret", "resolve_kernel_mode",
    "flash_attention", "fused_edge_aggregate",
    "fused_edge_aggregate_batched", "fused_mix_and_update",
    "fused_sgd_update",
]
