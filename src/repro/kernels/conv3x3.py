"""Fused 3x3 im2col conv Pallas kernel (matmul + bias + ReLU epilogue).

The im2col conv is the single hottest op in the CNN train step
(EXPERIMENTS.md §Perf): every masked-SGD scan iteration issues one
``[B·H·W, 9·Cin] × [9·Cin, Cout]`` matmul per conv layer, then bounces
back through XLA for the bias add and the ReLU.  This kernel fuses the
epilogue into the matmul tile: each program instance holds one
``[TILE_M, 9·Cin]`` block of the im2col patches in VMEM, contracts it
against the full (small) weight matrix on the MXU with f32 accumulation,
and applies bias + ReLU before the tile ever leaves VMEM.

The im2col patch construction itself (pad + 9 shifted slices) stays in
XLA on purpose: it is a pure data-movement op whose transpose is exactly
col2im, so leaving it outside the kernel gives the dx gradient for free
through XLA's autodiff while the custom VJP below covers only the
matmul + bias + ReLU core:

  forward   y  = relu(cols @ W + b)
  backward  dz = dy * (y > 0)
            dcols = dz @ Wᵀ          (per tile, fused)
            dW    = colsᵀ @ dz       (per-tile partials, summed in XLA)
            db    = Σ dz

Both backward matmuls run in the same tiled pass.  The per-tile dW/db
partials land in a small ``[num_tiles, ...]`` scratch output and are
reduced outside the kernel — no cross-program accumulation, so the
kernel stays correct under ``vmap`` (the engine's stacked device axis
and the sweep fabric's ``[P]`` point axis are prepended as grid
dimensions by Pallas batching).

Padding: M is padded to a TILE_M multiple with zero rows.  Forward pad
rows compute ``relu(b)`` and are sliced off; backward pad rows carry
``dy = 0`` so ``dz = 0`` and they contribute exactly nothing to dW/db.

Oracle: ``ref.conv3x3_bias_relu_ref``.  Backend selection lives in
``kernels.dispatch.conv3x3_bias_relu``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret

#: Rows of the im2col matrix per program instance.  At the paper's DEFAULT
#: geometry (K = 9·32 = 288, Cout = 64) one block is 256·288·4 ≈ 0.3 MB —
#: well inside VMEM next to the full weight matrix (288·64·4 ≈ 74 kB).
TILE_M = 256


def _fwd_kernel(cols_ref, w_ref, b_ref, out_ref):
    """One [TILE_M, K] block: relu(cols @ W + b), f32 accumulation."""
    f32 = jnp.float32
    acc = jnp.dot(cols_ref[...].astype(f32), w_ref[...].astype(f32),
                  preferred_element_type=f32)
    acc = acc + b_ref[...].astype(f32)          # b_ref [1, N]
    out_ref[...] = jnp.maximum(acc, 0.0).astype(out_ref.dtype)


def _bwd_kernel(cols_ref, w_ref, y_ref, dy_ref,
                dcols_ref, dw_ref, db_ref):
    """Backward tile: relu grad + both matmuls.  dw/db are per-tile
    partials written to [1, K, N] / [1, 1, N] blocks (summed outside)."""
    f32 = jnp.float32
    dz = dy_ref[...].astype(f32) * (y_ref[...].astype(f32) > 0.0)
    w = w_ref[...].astype(f32)
    dcols_ref[...] = jnp.dot(dz, w.T,
                             preferred_element_type=f32
                             ).astype(dcols_ref.dtype)
    dw_ref[...] = jnp.dot(cols_ref[...].astype(f32).T, dz,
                          preferred_element_type=f32)[None]
    db_ref[...] = jnp.sum(dz, axis=0)[None, None]


def _pad_m(a: jnp.ndarray, pad: int) -> jnp.ndarray:
    return jnp.pad(a, ((0, pad), (0, 0))) if pad else a


@functools.partial(jax.jit, static_argnames=("interpret",))
def _fwd_call(interpret: bool, cols: jnp.ndarray, wmat: jnp.ndarray,
              bias: jnp.ndarray) -> jnp.ndarray:
    m, k = cols.shape
    n = wmat.shape[1]
    pad = (-m) % TILE_M
    mp = m + pad
    y = pl.pallas_call(
        _fwd_kernel,
        grid=(mp // TILE_M,),
        in_specs=[
            pl.BlockSpec((TILE_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), cols.dtype),
        interpret=interpret,
    )(_pad_m(cols, pad), wmat, bias[None, :])
    return y[:m]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _bwd_call(interpret: bool, cols: jnp.ndarray, wmat: jnp.ndarray,
              y: jnp.ndarray, dy: jnp.ndarray):
    m, k = cols.shape
    n = wmat.shape[1]
    pad = (-m) % TILE_M
    mp = m + pad
    nt = mp // TILE_M
    dcols, dw_part, db_part = pl.pallas_call(
        _bwd_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((TILE_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((TILE_M, n), lambda i: (i, 0)),
            pl.BlockSpec((TILE_M, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_M, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k), cols.dtype),
            jax.ShapeDtypeStruct((nt, k, n), jnp.float32),
            jax.ShapeDtypeStruct((nt, 1, n), jnp.float32),
        ],
        interpret=interpret,
    )(_pad_m(cols, pad), wmat, _pad_m(y, pad), _pad_m(dy, pad))
    dw = jnp.sum(dw_part, axis=0).astype(wmat.dtype)
    db = jnp.sum(db_part, axis=0)[0].astype(wmat.dtype)
    return dcols[:m], dw, db


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _matmul_bias_relu(interpret: bool, cols: jnp.ndarray,
                      wmat: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """relu(cols @ wmat + bias) with both passes fused in Pallas."""
    return _fwd_call(interpret, cols, wmat, bias)


def _mbr_fwd(interpret, cols, wmat, bias):
    y = _fwd_call(interpret, cols, wmat, bias)
    return y, (cols, wmat, y)


def _mbr_bwd(interpret, res, dy):
    cols, wmat, y = res
    return _bwd_call(interpret, cols, wmat, y, dy)


_matmul_bias_relu.defvjp(_mbr_fwd, _mbr_bwd)


def conv3x3_bias_relu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Fused ``relu(conv3x3_same(x, w) + b)`` — the CNN conv block.

    x: [..., H, W, Cin]; w: [3, 3, Cin, Cout]; b: [Cout].  Semantics =
    ``ref.conv3x3_bias_relu_ref`` (im2col matmul with f32 accumulation,
    outputs cast back to ``x.dtype``).  Differentiable in x/w/b via the
    fused backward kernel; ``interpret=None`` auto-detects the backend
    (``dispatch.default_interpret``).
    """
    if interpret is None:
        interpret = default_interpret()
    h, wd = x.shape[-3], x.shape[-2]
    cin, cout = w.shape[2], w.shape[3]
    pad = [(0, 0)] * (x.ndim - 3) + [(1, 1), (1, 1), (0, 0)]
    xp = jnp.pad(x, pad)
    # (i, j, c)-ordered patch channels match w.reshape(9*Cin, Cout) —
    # identical layout to models.cnn._conv3x3_same_im2col.
    cols = jnp.concatenate([xp[..., i:i + h, j:j + wd, :]
                            for i in range(3) for j in range(3)], axis=-1)
    y = _matmul_bias_relu(bool(interpret), cols.reshape(-1, 9 * cin),
                          w.reshape(9 * cin, cout), b)
    return y.reshape(x.shape[:-1] + (cout,))
