"""Fused HieAvg aggregation Pallas kernel (TPU target, VMEM-tiled).

The aggregation step is the paper's compute hot-spot at framework scale:
HieAvg touches every parameter of every client several times per round
(estimate stragglers, weighted mix, history update) — a pure HBM-bandwidth
problem.  XLA emits ~7 separate elementwise passes over the [n, L] stacked
weights; this kernel fuses mask-select, decay-scaled estimation
``γ(w_prev + Δ̄)``, the weighted mean across participants, and the history
update (new ``w_prev``, running ``Δ̄``) into ONE pass over HBM.

Tiling: grid over the flat parameter axis; each program instance holds an
``[n, TILE]`` block of the three [n, L] operands in VMEM (n ≤ 32 clients,
TILE = 2048 f32 lanes → ≤ 0.8 MB/operand·block, comfortably inside the
~16 MB VMEM budget) and writes the aggregate tile plus both history tiles.
The per-participant coefficients (mask, γ-decay, 1/J weights) are tiny [n]
vectors computed outside and broadcast in VMEM.

Batched callers (the engine's ``[N, J, ...]`` dense layout, the sweep
fabric's stacked ``[P]`` point axis) ``vmap`` this kernel — Pallas
prepends the mapped axes as grid dimensions (see
``ops.fused_edge_aggregate_batched``).  Backend selection (compiled vs
interpreter vs the XLA reference path) lives in ``kernels.dispatch``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret

TILE = 2048


def _kernel(w_ref, prev_ref, dmean_ref, vec_ref,
            agg_ref, nprev_ref, ndmean_ref):
    """One [n, TILE] block.  vec_ref: [4, n] f32 = (mask, coef_present,
    coef_est, n_obs)."""
    f32 = jnp.float32
    w = w_ref[...].astype(f32)          # [n, T]
    prev = prev_ref[...].astype(f32)
    dmean = dmean_ref[...].astype(f32)
    m = vec_ref[0, :][:, None]          # [n, 1]
    cp = vec_ref[1, :][:, None]
    ce = vec_ref[2, :][:, None]
    nb = vec_ref[3, :][:, None]

    est = prev + dmean
    agg_ref[...] = jnp.sum(cp * w + ce * est, axis=0,
                           keepdims=True).astype(agg_ref.dtype)
    nprev_ref[...] = (m * w + (1.0 - m) * est).astype(nprev_ref.dtype)
    new_mean = (dmean * nb + (w - prev)) / (nb + 1.0)
    ndmean_ref[...] = (m * new_mean + (1.0 - m) * dmean
                       ).astype(ndmean_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hieavg_agg(w: jnp.ndarray, prev: jnp.ndarray, dmean: jnp.ndarray,
               mask: jnp.ndarray, coef_present: jnp.ndarray,
               coef_est: jnp.ndarray, n_obs: jnp.ndarray,
               interpret: bool | None = None):
    """Fused aggregate + history update on one flat [n, L] leaf.

    Returns (agg [L], new_prev [n, L], new_dmean [n, L]).  Semantics =
    ``repro.kernels.ref.hieavg_agg_ref``.  ``interpret=None`` auto-detects
    the backend (``dispatch.default_interpret``): compiled ``pallas_call``
    on TPU/GPU, interpreter on CPU.  History leaves (``prev``/``dmean``)
    may carry a narrower storage dtype than ``w`` (the engine's
    ``history_dtype`` knob) — math is f32, each output casts back to its
    own operand's dtype.
    """
    if interpret is None:
        interpret = default_interpret()
    n, l = w.shape
    pad = (-l) % TILE
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        prev = jnp.pad(prev, ((0, 0), (0, pad)))
        dmean = jnp.pad(dmean, ((0, 0), (0, pad)))
    lp = l + pad
    vec = jnp.stack([mask.astype(jnp.float32),
                     coef_present.astype(jnp.float32),
                     coef_est.astype(jnp.float32),
                     n_obs.astype(jnp.float32)])           # [4, n]

    grid = (lp // TILE,)
    agg, nprev, ndmean = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
            pl.BlockSpec((4, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE), lambda i: (0, i)),
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, lp), w.dtype),
            jax.ShapeDtypeStruct((n, lp), prev.dtype),
            jax.ShapeDtypeStruct((n, lp), dmean.dtype),
        ],
        interpret=interpret,
    )(w, prev, dmean, vec)
    return agg[0, :l], nprev[:, :l], ndmean[:, :l]
