"""Fused masked-SGD update Pallas kernel (TPU target, VMEM-tiled).

The engine's train-step inner loop applies ``w ← w − (lr·ok)·g`` to every
parameter of every device, every SGD step of every edge round — after the
HieAvg aggregation this is the second HBM-bandwidth hot-spot of a run.
The lr scale and the sweep fabric's padded-step mask are folded into ONE
scalar by the caller (``ok`` ∈ {0, 1}, so a padded step is an exact
identity), and the kernel does the whole read-modify-write in a single
pass over each ``[n, L]`` leaf: read w and g once, write w′ once.

Tiling mirrors ``hieavg_agg``: grid over the flat parameter axis, each
program instance holds an ``[n, TILE]`` block of w and g in VMEM
(n = stacked devices ≤ ~32, TILE = 2048 f32 lanes) plus the broadcast
``[1, 1]`` scale; math in f32, outputs cast back to the storage dtype.

Semantics contract: ``repro.kernels.ref.sgd_update_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret

TILE = 2048


def _kernel(w_ref, g_ref, s_ref, out_ref):
    """One [n, TILE] block: out = w - s*g, f32 math."""
    f32 = jnp.float32
    s = s_ref[0, 0]
    out_ref[...] = (w_ref[...].astype(f32)
                    - s * g_ref[...].astype(f32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sgd_update(w: jnp.ndarray, g: jnp.ndarray, scale: jnp.ndarray,
               interpret: bool | None = None) -> jnp.ndarray:
    """Fused SGD update on one flat [n, L] leaf: ``w - scale * g``.

    ``scale`` is a (possibly traced) scalar — lr × step-validity, so 0
    makes the update an exact identity.  ``interpret=None`` auto-detects
    the backend (compiled on TPU/GPU, interpreter on CPU).  Semantics =
    ``repro.kernels.ref.sgd_update_ref``.
    """
    if interpret is None:
        interpret = default_interpret()
    n, l = w.shape
    pad = (-l) % TILE
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        g = jnp.pad(g, ((0, 0), (0, pad)))
    lp = l + pad
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _kernel,
        grid=(lp // TILE,),
        in_specs=[
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
            pl.BlockSpec((n, TILE), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, lp), w.dtype),
        interpret=interpret,
    )(w, g, s)
    return out[:, :l]
