"""Fused eval-head Pallas kernel: logits → argmax → correct-count.

The engine's post-scan eval maps ``cnn_accuracy_fast`` over every global
round: a dense matmul for the logits, an argmax, an equality compare and
a mean — three extra XLA passes over a ``[M, C]`` logits buffer that is
never needed again.  This kernel folds the whole chain into the matmul
tile: each program instance contracts a ``[TILE_M, F]`` block of pooled
features against the full classifier matrix, takes the row argmax and
compares against the labels without the logits ever leaving VMEM.  Per
tile it emits a single ``[1, 1]`` int32 correct-count, and the tiny
``[num_tiles, 1]`` partials are summed in XLA — no cross-program
accumulation, so the kernel stays correct under ``vmap`` (sweep ``[P]``
axes prepend as grid dims).

Padding: M is padded to a TILE_M multiple with zero feature rows and
``label = -1`` — argmax is always ≥ 0, so padded rows can never count as
correct (an exact no-op, matching the kernel plane's padded-slot
contract).

Oracle: ``ref.eval_head_ref``.  Backend selection lives in
``kernels.dispatch.eval_head``; ``models.cnn.cnn_accuracy_fast`` divides
the count by the true row count to return an accuracy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret

TILE_M = 256


def _kernel(f_ref, w_ref, b_ref, y_ref, out_ref):
    """One [TILE_M, F] block: correct-count of argmax(f @ W + b) vs y."""
    f32 = jnp.float32
    logits = jnp.dot(f_ref[...].astype(f32), w_ref[...].astype(f32),
                     preferred_element_type=f32) + b_ref[...].astype(f32)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # [TILE_M]
    hit = (pred == y_ref[...][:, 0]).astype(jnp.int32)
    out_ref[0, 0] = jnp.sum(hit)


@functools.partial(jax.jit, static_argnames=("interpret",))
def eval_head(feats: jnp.ndarray, wmat: jnp.ndarray, bias: jnp.ndarray,
              labels: jnp.ndarray, interpret: bool | None = None
              ) -> jnp.ndarray:
    """Correct-prediction count of the classifier head in one fused pass.

    feats: [M, F]; wmat: [F, C]; bias: [C]; labels: [M] int.  Returns a
    scalar int32 count of rows where ``argmax(feats @ wmat + bias) ==
    labels``.  Semantics = ``ref.eval_head_ref`` (f32 logits math, first-
    max-wins argmax).  ``interpret=None`` auto-detects the backend.
    """
    if interpret is None:
        interpret = default_interpret()
    m, f = feats.shape
    c = wmat.shape[1]
    pad = (-m) % TILE_M
    mp = m + pad
    nt = mp // TILE_M
    if pad:
        feats = jnp.pad(feats, ((0, pad), (0, 0)))
    lab = jnp.full((mp, 1), -1, jnp.int32)
    lab = lab.at[:m, 0].set(labels.astype(jnp.int32))
    counts = pl.pallas_call(
        _kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((TILE_M, f), lambda i: (i, 0)),
            pl.BlockSpec((f, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((TILE_M, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, 1), jnp.int32),
        interpret=interpret,
    )(feats, wmat, bias[None, :], lab)
    return jnp.sum(counts)
