"""Public jit'd wrappers around the Pallas kernels.

The HieAvg entry points mirror ``repro.core.hieavg`` semantics on stacked
pytrees, dispatching each leaf (flattened to ``[n, L]``) through the fused
``hieavg_agg`` kernel — one HBM pass per leaf instead of XLA's ~7:

  * ``fused_mix_and_update`` — the kernel analogue of
    ``hieavg._mix_and_update`` (eq. 4/5): traced ``part_weights`` /
    ``gamma0`` / ``lam``, composes under ``vmap``/``scan`` inside the
    engine's compiled program.
  * ``fused_edge_aggregate_batched`` — the engine's dense layer API
    (eq. 4 for all N edges at once): ``[N, J, ...]`` stacked leaves, a
    ``valid`` mask whose padded slots carry zero part weight (numeric
    no-ops, exactly like ``hieavg.edge_aggregate_batched``), the kernel
    vmapped over the edge axis (Pallas prepends it — and the sweep
    fabric's stacked ``[P]`` point axis above it — as grid dimensions).
  * ``fused_edge_aggregate`` — the original single-edge API (eq. 4,
    static ``gamma0``/``lam``), kept for direct callers and benchmarks.

``fused_sgd_update`` is the train-step inner loop: the masked SGD update
``w − (lr·ok)·g`` in one pass per leaf (``kernels.sgd_update``).

``conv3x3_bias_relu`` / ``eval_head`` (re-exported from their kernel
modules) and the ``fused_coef_aggregate`` pair close the rest of the
round: the CNN conv block with its fused bias+ReLU epilogue and custom
VJP, the classifier-head correct-count eval, and the generalized
coefficient aggregate shared by the cold-boot means, FedAvg and the
delayed-gradient mix (zero-coefficient padded slots stay exact no-ops).

``flash_attention`` is the multi-head GQA front-end of the single-head
kernel: batch, kv-head and group dims are vmapped (Pallas prepends them as
grid dimensions).

Every wrapper takes ``interpret=None`` = backend auto-detection
(``dispatch.default_interpret``): compiled ``pallas_call`` on TPU/GPU,
interpreter on CPU.  The engine does not call these directly — it goes
through ``kernels.dispatch`` so ``kernel_mode="xla"``/``"auto"`` can route
to the pure-XLA reference path instead.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.hieavg import History
from .coef_agg import coef_agg, coef_agg_pair
from .conv3x3 import conv3x3_bias_relu
from .dispatch import default_interpret
from .eval_head import eval_head
from .flash_attention import flash_attention_1h
from .hieavg_agg import hieavg_agg
from .sgd_update import sgd_update

PyTree = Any


# ----------------------------------------------------------------- hieavg
def fused_mix_and_update(stacked_w: PyTree, mask: jnp.ndarray,
                         history: History, part_weights: jnp.ndarray,
                         gamma0, lam, normalize: bool = False, *,
                         interpret: Optional[bool] = None
                         ) -> tuple[PyTree, History]:
    """Kernel-fused ``hieavg._mix_and_update`` (eq. 4/5) on [n, ...] leaves.

    ``part_weights``/``gamma0``/``lam`` may be traced (the engine sweeps
    decay factors as data) — the tiny per-participant coefficient vectors
    are computed in XLA and broadcast into the kernel, which does the
    heavy [n, L] mix + history update in one HBM pass per leaf.  An
    all-zero ``part_weights`` row (sweep-fabric padding) contributes
    exactly nothing.  Returns (aggregate, updated History) — allclose to
    the core path; no jit boundary, composes under vmap/scan.
    """
    if interpret is None:
        interpret = default_interpret()
    m = mask.astype(jnp.float32)
    gamma = gamma0 * lam ** (history.miss_count + 1.0)    # k' >= 1
    coef = part_weights * (m + (1.0 - m) * gamma)
    if normalize:
        coef = coef / jnp.maximum(jnp.sum(coef), 1e-12)
    coef_present = coef * m
    coef_est = coef * (1.0 - m)
    n = mask.shape[0]

    leaves_w, treedef = jax.tree_util.tree_flatten(stacked_w)
    leaves_p = treedef.flatten_up_to(history.prev_w)
    leaves_d = treedef.flatten_up_to(history.delta_mean)

    aggs, nprevs, ndmeans = [], [], []
    for w, p, d in zip(leaves_w, leaves_p, leaves_d):
        flat = (n, -1)
        a, np_, nd = hieavg_agg(w.reshape(flat), p.reshape(flat),
                                d.reshape(flat), mask, coef_present,
                                coef_est, history.n_obs,
                                interpret=interpret)
        aggs.append(a.reshape(w.shape[1:]))
        nprevs.append(np_.reshape(p.shape))
        ndmeans.append(nd.reshape(d.shape))

    new_hist = History(
        prev_w=jax.tree_util.tree_unflatten(treedef, nprevs),
        delta_mean=jax.tree_util.tree_unflatten(treedef, ndmeans),
        n_obs=history.n_obs + m,
        miss_count=(history.miss_count + 1.0) * (1.0 - m),
    )
    return jax.tree_util.tree_unflatten(treedef, aggs), new_hist


def fused_edge_aggregate_batched(stacked_w: PyTree, mask: jnp.ndarray,
                                 history: History, valid: jnp.ndarray,
                                 gamma0, lam, normalize: bool = False, *,
                                 interpret: Optional[bool] = None
                                 ) -> tuple[PyTree, History]:
    """Eq. (4) for ALL N edges through the fused kernel in one vmapped call.

    Mirrors ``hieavg.edge_aggregate_batched`` exactly: stacked_w leaves
    ``[N, J, ...]``, mask/valid ``[N, J]``, per-edge part weights
    ``valid / J_e`` (zero on padded slots, so padding stays a numeric
    no-op).  The edge axis is vmapped over the kernel — Pallas prepends it
    (and any sweep-stacked ``[P]`` axis above) as grid dimensions, so one
    ``pallas_call`` per leaf covers the whole dense layout.
    """
    if interpret is None:
        interpret = default_interpret()
    v = valid.astype(jnp.float32)
    pw = v / jnp.maximum(jnp.sum(v, axis=-1, keepdims=True), 1.0)

    def one_edge(w, m, h, p):
        return fused_mix_and_update(w, m, h, p, gamma0, lam, normalize,
                                    interpret=interpret)

    return jax.vmap(one_edge)(stacked_w, mask, history, pw)


@functools.partial(jax.jit, static_argnames=("gamma0", "lam", "normalize",
                                             "interpret"))
def fused_edge_aggregate(stacked_w: PyTree, mask: jnp.ndarray,
                         history: History, *, gamma0: float = 0.9,
                         lam: float = 0.9, normalize: bool = False,
                         interpret: Optional[bool] = None
                         ) -> tuple[PyTree, History]:
    """Kernel-fused equivalent of ``hieavg.edge_aggregate`` (eq. 4).

    The single-edge API (uniform 1/n part weights, static decay factors)
    — direct callers and ``benchmarks/kernel_bench``.  Returns
    (edge model, updated History) — allclose to the core path.
    """
    n = mask.shape[0]
    pw = jnp.full((n,), 1.0 / n, jnp.float32)
    return fused_mix_and_update(stacked_w, mask, history, pw, gamma0, lam,
                                normalize, interpret=interpret)


# --------------------------------------------------------------- coef agg
def fused_coef_aggregate(stacked_w: PyTree, coef: jnp.ndarray, *,
                         interpret: Optional[bool] = None) -> PyTree:
    """``Σ_n coef[n] · w[n]`` per leaf in one fused pass (f32 outputs).

    The shared core of the cold-boot means and FedAvg: the caller bakes
    every normalization into ``coef`` (see ``dispatch``), so zero-coef
    padded slots are exact no-ops.  Leaves ``[n, ...]`` → ``[...]``.
    """
    if interpret is None:
        interpret = default_interpret()

    def one(w):
        n = w.shape[0]
        return coef_agg(w.reshape(n, -1), coef,
                        interpret=interpret).reshape(w.shape[1:])

    return jax.tree.map(one, stacked_w)


def fused_coef_aggregate_pair(stacked_w: PyTree, aux: PyTree,
                              ca: jnp.ndarray, cb: jnp.ndarray, *,
                              interpret: Optional[bool] = None) -> PyTree:
    """``Σ_n ca[n]·w[n] + cb[n]·aux[n]`` per leaf (delayed-grad mix)."""
    if interpret is None:
        interpret = default_interpret()

    def one(w, a):
        n = w.shape[0]
        return coef_agg_pair(w.reshape(n, -1), a.reshape(n, -1), ca, cb,
                             interpret=interpret).reshape(w.shape[1:])

    return jax.tree.map(one, stacked_w, aux)


# -------------------------------------------------------------------- sgd
def fused_sgd_update(params: PyTree, grads: PyTree, scale, *,
                     interpret: Optional[bool] = None) -> PyTree:
    """Masked SGD update ``w − scale·g`` in one fused pass per leaf.

    ``scale`` is the (traced) lr × step-validity scalar — the sweep
    fabric's padded steps pass 0 and the update is an exact identity.
    Leaves carry a leading stacked-device dim ``[D, ...]`` and are
    flattened to ``[D, L]`` for the kernel.  Oracle:
    ``ref.sgd_update_ref``; XLA reference path: the engine's plain
    ``tree.map`` (``dispatch.sgd_update(mode="xla")``).
    """
    if interpret is None:
        interpret = default_interpret()

    def one(w, g):
        n = w.shape[0]
        out = sgd_update(w.reshape(n, -1), g.reshape(n, -1), scale,
                         interpret=interpret)
        return out.reshape(w.shape)

    return jax.tree.map(one, params, grads)


# ------------------------------------------------------------------ flash
@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, interpret: Optional[bool] = None
                    ) -> jnp.ndarray:
    """GQA flash attention. q [B,Sq,H,Dh]; k/v [B,Skv,Hkv,Dh] -> like q.

    Matches ``repro.models.attention._sdpa`` semantics (scale 1/sqrt(Dh)).
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)

    fn = functools.partial(flash_attention_1h, causal=causal, window=window,
                           q_offset=q_offset, interpret=interpret)
    # [B, Hkv, G] prepended as grid dims by vmap (outermost applied last;
    # each vmap strips the leading mapped axis of the operands it maps)
    fn = jax.vmap(fn, in_axes=(0, None, None))        # G (q only)
    fn = jax.vmap(fn, in_axes=(0, 0, 0))              # Hkv
    fn = jax.vmap(fn, in_axes=(0, 0, 0))              # B
    qb = jnp.moveaxis(qg, 1, -2)                      # [B, Hkv, G, Sq, Dh]
    kb = jnp.moveaxis(k, 1, -2)                       # [B, Hkv, Skv, Dh]
    out = fn(qb, kb, jnp.moveaxis(v, 1, -2))          # [B, Hkv, G, Sq, Dh]
    return jnp.moveaxis(out, -2, 1).reshape(b, sq, h, dh)
