"""Public jit'd wrappers around the Pallas kernels.

``fused_edge_aggregate`` mirrors ``repro.core.hieavg.edge_aggregate``'s
semantics on a stacked pytree, dispatching each leaf (flattened to [n, L])
through the fused kernel — one HBM pass per leaf instead of XLA's ~7.

``flash_attention`` is the multi-head GQA front-end of the single-head
kernel: batch, kv-head and group dims are vmapped (Pallas prepends them as
grid dimensions).

``interpret=True`` everywhere in this container (CPU validation of a TPU
kernel); the launch layer flips it off on real hardware.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.hieavg import History
from .flash_attention import flash_attention_1h
from .hieavg_agg import hieavg_agg

PyTree = Any


# ----------------------------------------------------------------- hieavg
@functools.partial(jax.jit, static_argnames=("gamma0", "lam", "normalize",
                                             "interpret"))
def fused_edge_aggregate(stacked_w: PyTree, mask: jnp.ndarray,
                         history: History, *, gamma0: float = 0.9,
                         lam: float = 0.9, normalize: bool = False,
                         interpret: bool = True) -> tuple[PyTree, History]:
    """Kernel-fused equivalent of ``hieavg.edge_aggregate`` (eq. 4).

    Returns (edge model, updated History) — allclose to the core path.
    """
    n = mask.shape[0]
    m = mask.astype(jnp.float32)
    part_weights = jnp.full((n,), 1.0 / n, jnp.float32)
    gamma = gamma0 * lam ** (history.miss_count + 1.0)
    coef = part_weights * (m + (1.0 - m) * gamma)
    if normalize:
        coef = coef / jnp.maximum(jnp.sum(coef), 1e-12)
    coef_present = coef * m
    coef_est = coef * (1.0 - m)

    leaves_w, treedef = jax.tree_util.tree_flatten(stacked_w)
    leaves_p = treedef.flatten_up_to(history.prev_w)
    leaves_d = treedef.flatten_up_to(history.delta_mean)

    aggs, nprevs, ndmeans = [], [], []
    for w, p, d in zip(leaves_w, leaves_p, leaves_d):
        flat = (n, -1)
        a, np_, nd = hieavg_agg(w.reshape(flat), p.reshape(flat),
                                d.reshape(flat), mask, coef_present,
                                coef_est, history.n_obs,
                                interpret=interpret)
        aggs.append(a.reshape(w.shape[1:]))
        nprevs.append(np_.reshape(p.shape))
        ndmeans.append(nd.reshape(d.shape))

    new_hist = History(
        prev_w=jax.tree_util.tree_unflatten(treedef, nprevs),
        delta_mean=jax.tree_util.tree_unflatten(treedef, ndmeans),
        n_obs=history.n_obs + m,
        miss_count=(history.miss_count + 1.0) * (1.0 - m),
    )
    return jax.tree_util.tree_unflatten(treedef, aggs), new_hist


# ------------------------------------------------------------------ flash
@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, interpret: bool = True
                    ) -> jnp.ndarray:
    """GQA flash attention. q [B,Sq,H,Dh]; k/v [B,Skv,Hkv,Dh] -> like q.

    Matches ``repro.models.attention._sdpa`` semantics (scale 1/sqrt(Dh)).
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)

    fn = functools.partial(flash_attention_1h, causal=causal, window=window,
                           q_offset=q_offset, interpret=interpret)
    # [B, Hkv, G] prepended as grid dims by vmap (outermost applied last;
    # each vmap strips the leading mapped axis of the operands it maps)
    fn = jax.vmap(fn, in_axes=(0, None, None))        # G (q only)
    fn = jax.vmap(fn, in_axes=(0, 0, 0))              # Hkv
    fn = jax.vmap(fn, in_axes=(0, 0, 0))              # B
    qb = jnp.moveaxis(qg, 1, -2)                      # [B, Hkv, G, Sq, Dh]
    kb = jnp.moveaxis(k, 1, -2)                       # [B, Hkv, Skv, Dh]
    out = fn(qb, kb, jnp.moveaxis(v, 1, -2))          # [B, Hkv, G, Sq, Dh]
    return jnp.moveaxis(out, -2, 1).reshape(b, sq, h, dh)
