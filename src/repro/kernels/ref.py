"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are the *semantics* contracts: kernels must match them on every
shape/dtype the tests sweep.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- hieavg_agg
def hieavg_agg_ref(w: jnp.ndarray, prev: jnp.ndarray, dmean: jnp.ndarray,
                   mask: jnp.ndarray, coef_present: jnp.ndarray,
                   coef_est: jnp.ndarray, n_obs: jnp.ndarray
                   ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused HieAvg mix + history update on one flat leaf.

    w/prev/dmean: [n, L]; mask/coefs/n_obs: [n].
      agg       = sum_n coef_present*w + coef_est*(prev + dmean)
      new_prev  = m*w + (1-m)*(prev + dmean)
      new_dmean = m*((dmean*n_obs + (w - prev)) / (n_obs+1)) + (1-m)*dmean
    Returns (agg [L], new_prev [n, L], new_dmean [n, L]); all math f32,
    outputs cast back to input dtypes.
    """
    f32 = jnp.float32
    wf, pf, df = w.astype(f32), prev.astype(f32), dmean.astype(f32)
    m = mask.astype(f32)[:, None]
    cp = coef_present.astype(f32)[:, None]
    ce = coef_est.astype(f32)[:, None]
    nb = n_obs.astype(f32)[:, None]
    est = pf + df
    agg = jnp.sum(cp * wf + ce * est, axis=0)
    new_prev = m * wf + (1.0 - m) * est
    new_dmean = m * ((df * nb + (wf - pf)) / (nb + 1.0)) + (1.0 - m) * df
    return (agg.astype(w.dtype), new_prev.astype(prev.dtype),
            new_dmean.astype(dmean.dtype))


# -------------------------------------------------------------- sgd_update
def sgd_update_ref(w: jnp.ndarray, g: jnp.ndarray, scale) -> jnp.ndarray:
    """Masked SGD update on one flat leaf: ``w - scale * g``.

    ``scale`` is a scalar (lr × step-validity — 0 for a padded sweep step,
    which makes the update an exact identity).  Math in f32, output cast
    back to ``w.dtype``.
    """
    f32 = jnp.float32
    s = jnp.asarray(scale, f32)
    return (w.astype(f32) - s * g.astype(f32)).astype(w.dtype)


# --------------------------------------------------------- flash attention
def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int = 0) -> jnp.ndarray:
    """Single-head attention oracle. q: [Sq, D]; k/v: [Skv, D] -> [Sq, D].

    Scale 1/sqrt(D); causal/window masks computed from absolute positions
    (q row i has absolute position q_offset + i).
    """
    sq, d = q.shape
    skv = k.shape[0]
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T
              ) / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    logits = jnp.where(ok, logits, -2.0 ** 30)
    probs = jax.nn.softmax(logits, axis=-1)
    return (probs @ v.astype(jnp.float32)).astype(v.dtype)
