"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are the *semantics* contracts: kernels must match them on every
shape/dtype the tests sweep.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- hieavg_agg
def hieavg_agg_ref(w: jnp.ndarray, prev: jnp.ndarray, dmean: jnp.ndarray,
                   mask: jnp.ndarray, coef_present: jnp.ndarray,
                   coef_est: jnp.ndarray, n_obs: jnp.ndarray
                   ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused HieAvg mix + history update on one flat leaf.

    w/prev/dmean: [n, L]; mask/coefs/n_obs: [n].
      agg       = sum_n coef_present*w + coef_est*(prev + dmean)
      new_prev  = m*w + (1-m)*(prev + dmean)
      new_dmean = m*((dmean*n_obs + (w - prev)) / (n_obs+1)) + (1-m)*dmean
    Returns (agg [L], new_prev [n, L], new_dmean [n, L]); all math f32,
    outputs cast back to input dtypes.
    """
    f32 = jnp.float32
    wf, pf, df = w.astype(f32), prev.astype(f32), dmean.astype(f32)
    m = mask.astype(f32)[:, None]
    cp = coef_present.astype(f32)[:, None]
    ce = coef_est.astype(f32)[:, None]
    nb = n_obs.astype(f32)[:, None]
    est = pf + df
    agg = jnp.sum(cp * wf + ce * est, axis=0)
    new_prev = m * wf + (1.0 - m) * est
    new_dmean = m * ((df * nb + (wf - pf)) / (nb + 1.0)) + (1.0 - m) * df
    return (agg.astype(w.dtype), new_prev.astype(prev.dtype),
            new_dmean.astype(dmean.dtype))


# -------------------------------------------------------------- sgd_update
def sgd_update_ref(w: jnp.ndarray, g: jnp.ndarray, scale) -> jnp.ndarray:
    """Masked SGD update on one flat leaf: ``w - scale * g``.

    ``scale`` is a scalar (lr × step-validity — 0 for a padded sweep step,
    which makes the update an exact identity).  Math in f32, output cast
    back to ``w.dtype``.
    """
    f32 = jnp.float32
    s = jnp.asarray(scale, f32)
    return (w.astype(f32) - s * g.astype(f32)).astype(w.dtype)


# ------------------------------------------------------- conv3x3_bias_relu
def conv3x3_bias_relu_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                          ) -> jnp.ndarray:
    """Fused conv block oracle: ``relu(conv3x3_same(x, w) + b)``.

    x: [..., H, W, Cin]; w: [3, 3, Cin, Cout]; b: [Cout].  The im2col
    matmul with (i, j, c)-ordered patch channels — identical layout to
    ``models.cnn._conv3x3_same_im2col`` — with f32 accumulation, output
    cast back to ``x.dtype``.
    """
    f32 = jnp.float32
    h, wd = x.shape[-3], x.shape[-2]
    pad = [(0, 0)] * (x.ndim - 3) + [(1, 1), (1, 1), (0, 0)]
    xp = jnp.pad(x, pad)
    cols = jnp.concatenate([xp[..., i:i + h, j:j + wd, :]
                            for i in range(3) for j in range(3)], axis=-1)
    out = jnp.einsum("...k,ko->...o", cols.astype(f32),
                     w.reshape(-1, w.shape[-1]).astype(f32))
    return jnp.maximum(out + b.astype(f32), 0.0).astype(x.dtype)


# ---------------------------------------------------------------- eval_head
def eval_head_ref(feats: jnp.ndarray, wmat: jnp.ndarray, bias: jnp.ndarray,
                  labels: jnp.ndarray) -> jnp.ndarray:
    """Fused eval oracle: correct-count of the classifier head.

    feats: [M, F]; wmat: [F, C]; bias: [C]; labels: [M] int.  Returns the
    scalar int32 count of rows where ``argmax(feats @ wmat + bias)``
    (f32 logits, first-max-wins) equals the label.
    """
    f32 = jnp.float32
    logits = feats.astype(f32) @ wmat.astype(f32) + bias.astype(f32)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum((pred == labels.astype(jnp.int32)).astype(jnp.int32))


# ----------------------------------------------------------------- coef_agg
def coef_agg_ref(w: jnp.ndarray, coef: jnp.ndarray) -> jnp.ndarray:
    """Coefficient-weighted aggregate oracle: ``Σ_n coef[n] · w[n]``.

    w: [n, L]; coef: [n].  f32 math, f32 output (matching the XLA cold /
    FedAvg reference paths, where f32 coefficients promote the product).
    A zero coefficient makes its slot an exact no-op.
    """
    f32 = jnp.float32
    return jnp.sum(coef.astype(f32)[:, None] * w.astype(f32), axis=0)


def coef_agg_pair_ref(w: jnp.ndarray, aux: jnp.ndarray, ca: jnp.ndarray,
                      cb: jnp.ndarray) -> jnp.ndarray:
    """Pair-form aggregate oracle: ``Σ_n ca[n]·w[n] + cb[n]·aux[n]``.

    The delayed-gradient mix: present devices contribute fresh weights
    (``ca``), missing ones their stale pending update (``cb``·aux).
    """
    f32 = jnp.float32
    return jnp.sum(ca.astype(f32)[:, None] * w.astype(f32)
                   + cb.astype(f32)[:, None] * aux.astype(f32), axis=0)


# --------------------------------------------------------- flash attention
def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int = 0) -> jnp.ndarray:
    """Single-head attention oracle. q: [Sq, D]; k/v: [Skv, D] -> [Sq, D].

    Scale 1/sqrt(D); causal/window masks computed from absolute positions
    (q row i has absolute position q_offset + i).
    """
    sq, d = q.shape
    skv = k.shape[0]
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T
              ) / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    logits = jnp.where(ok, logits, -2.0 ** 30)
    probs = jax.nn.softmax(logits, axis=-1)
    return (probs @ v.astype(jnp.float32)).astype(v.dtype)
