"""Blocked flash attention Pallas kernel (TPU target, VMEM-tiled).

Online-softmax attention over KV blocks: for each q block the kernel sweeps
kv blocks keeping a running (max, sum, accumulator) in VMEM scratch —
softmax(QKᵀ)V without ever materializing the [Sq, Skv] logits in HBM.
Covers full-causal and sliding-window (the serving hot-spot for the 32k /
500k assigned shapes).

Grid: (nq, nk), kv innermost.  Blocks: q [BQ, D], k/v [BK, D] — BQ=BK=256
rows × D≤256 f32 lanes ≈ 0.26 MB per operand block; MXU-aligned (multiples
of 128).  GQA/batch are handled by ``vmap`` in ops.py (prepended grid dims).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dispatch import default_interpret

BQ = 256
BK = 256
NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: Optional[int], q_offset: int, nk: int,
            scale: float, skv: int):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)           # [BQ, D]
    k = k_ref[...].astype(jnp.float32)           # [BK, D]
    v = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [BQ,BK]

    qpos = q_offset + qi * BQ + jax.lax.broadcasted_iota(jnp.int32,
                                                         (BQ, BK), 0)
    kpos = ki * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
    ok = kpos < skv                              # mask padded kv rows
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                          # [BQ, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (all NEG_INF): keep exp at 0
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "interpret"))
def flash_attention_1h(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       causal: bool = True, window: Optional[int] = None,
                       q_offset: int = 0, interpret: bool | None = None
                       ) -> jnp.ndarray:
    """Single-head flash attention. q [Sq, D], k/v [Skv, D] -> [Sq, D].

    Sq/Skv are padded to the block sizes; D to 128 lanes.  Semantics =
    ``repro.kernels.ref.flash_attention_ref``.  ``interpret=None``
    auto-detects the backend (compiled on TPU/GPU, interpreter on CPU).
    """
    if interpret is None:
        interpret = default_interpret()
    sq, d = q.shape
    skv = k.shape[0]
    scale = 1.0 / (d ** 0.5)                      # pre-pad head_dim scale
    pq, pk_, pd = (-sq) % BQ, (-skv) % BK, (-d) % 128
    if pq or pd:
        q = jnp.pad(q, ((0, pq), (0, pd)))
    if pk_ or pd:
        k = jnp.pad(k, ((0, pk_), (0, pd)))
        v = jnp.pad(v, ((0, pk_), (0, pd)))
    nq, nk = q.shape[0] // BQ, k.shape[0] // BK
    dp = q.shape[1]

    kernel = functools.partial(
        _kernel, causal=causal, window=window, q_offset=q_offset, nk=nk,
        scale=scale, skv=skv)

    out = pl.pallas_call(
        kernel,
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((BQ, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((BK, dp), lambda i, j: (j, 0)),
            pl.BlockSpec((BK, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BQ, dp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], dp), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, dp), jnp.float32),    # acc
            pltpu.VMEM((BQ, 1), jnp.float32),     # running max
            pltpu.VMEM((BQ, 1), jnp.float32),     # running sum
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:sq, :d]
